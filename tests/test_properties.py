"""Property-based equivalence harness for the condensed/dedup stack.

Three families of properties over randomly generated condensed graphs
(1-3 chains, 1-2 layers, optional direct edges and self loops):

  (a) ``build_correction_streaming`` is byte-identical to
      ``build_correction`` for every chunking / budget / fold backend;
  (b) ring and idempotent algorithms on the condensed representation
      with a (streamed) correction match the same algorithm on the
      materialized expansion;
  (c) every dedup-family output (DEDUP-1 x4, DEDUP-2, BITMAP-1/2)
      covers exactly the expanded edge set with no duplicates.

The ``@given`` tests run under real hypothesis when it is installed and
degrade to skips via the conftest stub offline; the seeded ``_offline``
variants keep the same properties exercised either way.  Hypothesis
tests carry the ``tier2`` marker (see scripts/check.sh).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from conftest import expanded_simple_pairs, random_membership_graph
from oracle import (
    dense_adjacency,
    scc_labels_ref,
    shortest_paths_ref,
    triangle_counts_ref,
    weighted_dense_ref,
    widest_paths_ref,
)

from repro.core import algorithms, dedup, engine
from repro.core.condensed import (
    BipartiteEdges,
    Chain,
    CondensedGraph,
    ExpansionAccounting,
)
from repro.core.extract import extract
from repro.core.semiring import PLUS_TIMES
from repro.data.synth import dblp_catalog, tpch_catalog


# ---------------------------------------------------------------------------
# Random graph generator: the issue's strategy space — 1-3 chains of 1-2
# layers over one real node set, optional direct edges including self loops.
# ---------------------------------------------------------------------------

def random_condensed(rng: np.random.Generator) -> CondensedGraph:
    n_real = int(rng.integers(3, 16))
    chains = []
    for _ in range(int(rng.integers(1, 4))):
        layers = [int(rng.integers(2, 6)) for _ in range(int(rng.integers(1, 3)))]
        levels = [n_real] + layers + [n_real]
        edges = []
        for a, b in zip(levels, levels[1:]):
            ne = int(rng.integers(2, 4 * max(a, b)))
            edges.append(
                BipartiteEdges(
                    rng.integers(0, a, ne), rng.integers(0, b, ne), a, b
                )
            )
        chains.append(Chain(edges))
    direct = None
    if rng.random() < 0.7:
        nd = int(rng.integers(1, 2 * n_real))
        src = rng.integers(0, n_real, nd)
        dst = rng.integers(0, n_real, nd)
        if rng.random() < 0.5:  # force some self loops
            dst[: max(nd // 3, 1)] = src[: max(nd // 3, 1)]
        direct = BipartiteEdges(src, dst, n_real, n_real)
    return CondensedGraph(n_real, chains, direct)


def _assert_same_triples(ref, got):
    for name, a, b in zip(("src", "dst", "count"), ref, got):
        assert a.dtype == b.dtype, name
        assert np.array_equal(a, b), name


STREAMING_VARIANTS = [
    dict(chunk_rows=1),
    dict(chunk_rows=2),
    dict(chunk_rows=3),
    dict(chunk_rows=5),
    dict(chunk_rows=None),
    dict(budget_triples=8),
    dict(budget_triples=64),
    dict(budget_bytes=1024),
    dict(chunk_rows=2, device_fold=True),
    dict(budget_triples=32, device_fold=True),
]


# ---------------------------------------------------------------------------
# (a) streaming correction == batch correction
# ---------------------------------------------------------------------------

def _check_streaming_equivalence(seed: int) -> None:
    rng = np.random.default_rng(seed)
    g = random_condensed(rng)
    for drop in (True, False):
        ref = dedup.build_correction(g, drop_self_loops=drop)
        for kw in STREAMING_VARIANTS:
            got = dedup.build_correction_streaming(
                g, drop_self_loops=drop, **kw
            )
            _assert_same_triples(ref, tuple(got))
            assert got.accounting.n_chunks >= 1
    # the iterator's chunks refold into multiplicities() exactly
    ref_m = g.multiplicities()
    for chunk_rows in (1, 3, None):
        _assert_same_triples(ref_m, g.multiplicities(chunk_rows=chunk_rows))


@pytest.mark.tier2
@given(seed=st.integers(0, 100_000))
@settings(max_examples=40, deadline=None)
def test_streaming_correction_equals_batch(seed):
    _check_streaming_equivalence(seed)


@pytest.mark.parametrize("seed", [0, 1, 7, 42])
def test_streaming_correction_equals_batch_offline(seed):
    _check_streaming_equivalence(seed)


# ---------------------------------------------------------------------------
# (b) condensed + correction == algorithms on the expansion
# ---------------------------------------------------------------------------

def _check_algorithm_equivalence(seed: int) -> None:
    rng = np.random.default_rng(seed)
    g = random_condensed(rng)
    exp = engine.to_device(g.expand())
    corr = dedup.build_correction_streaming(g, budget_triples=64)
    cond = engine.to_device(g, correction=corr)

    x = rng.standard_normal(g.n_real).astype(np.float32)
    want = np.asarray(engine.propagate(exp, x, PLUS_TIMES))
    got = np.asarray(engine.propagate(cond, x, PLUS_TIMES))
    assert np.allclose(got, want, atol=1e-3)

    pr_want = np.asarray(algorithms.pagerank(exp, num_iters=10))
    pr_got = np.asarray(algorithms.pagerank(cond, num_iters=10))
    assert np.allclose(pr_got, pr_want, atol=1e-5)

    bfs_want = np.asarray(algorithms.bfs(exp, 0, max_iters=20))
    bfs_got = np.asarray(algorithms.bfs(cond, 0, max_iters=20))
    assert np.allclose(bfs_got, bfs_want)


@pytest.mark.tier2
@given(seed=st.integers(0, 100_000))
@settings(max_examples=25, deadline=None)
def test_condensed_with_correction_matches_expanded(seed):
    _check_algorithm_equivalence(seed)


@pytest.mark.parametrize("seed", [3, 11, 2024])
def test_condensed_with_correction_matches_expanded_offline(seed):
    _check_algorithm_equivalence(seed)


# ---------------------------------------------------------------------------
# (c) dedup family covers the expanded edge set exactly once
# ---------------------------------------------------------------------------

DEDUP1_FNS = [
    dedup.dedup1_naive_virtual_first,
    dedup.dedup1_naive_real_first,
    dedup.dedup1_greedy_real_first,
    dedup.dedup1_greedy_virtual_first,
]


def _check_dedup_family_exact_cover(seed: int) -> None:
    rng = np.random.default_rng(seed)
    g = random_membership_graph(
        int(rng.integers(4, 20)), int(rng.integers(1, 7)), 4, rng
    )
    want_off = expanded_simple_pairs(g)
    for fn in DEDUP1_FNS:
        res = fn(g, rng=np.random.default_rng(seed + 1))
        assert expanded_simple_pairs(res.graph) == want_off, fn.__name__
        s, d, m = res.graph.multiplicities()
        assert (m[s != d] <= 1).all(), fn.__name__
    rep2 = dedup.dedup2_greedy(g, rng=np.random.default_rng(seed))
    mult = rep2.pair_multiplicities()
    assert set(mult) == {p for p in want_off if p[0] < p[1]}
    assert all(c == 1 for c in mult.values())
    s_all, d_all, _ = g.multiplicities()
    want_all = set(zip(s_all.tolist(), d_all.tolist()))
    for fn in (dedup.bitmap1, dedup.bitmap2):
        u, v = fn(g).to_dedup_pairs()
        pairs = list(zip(u.tolist(), v.tolist()))
        assert len(pairs) == len(set(pairs)), fn.__name__
        assert set(pairs) == want_all, fn.__name__


@pytest.mark.tier2
@given(seed=st.integers(0, 100_000))
@settings(max_examples=25, deadline=None)
def test_dedup_family_exact_cover(seed):
    _check_dedup_family_exact_cover(seed)


@pytest.mark.parametrize("seed", [0, 5, 123])
def test_dedup_family_exact_cover_offline(seed):
    _check_dedup_family_exact_cover(seed)


# ---------------------------------------------------------------------------
# (d) Condensation-native analytics vs the dense-expansion oracle
# (DESIGN.md §11): random catalogs -> extract -> condensed graph; SCC
# labels, triangle counts, and min-plus distances must equal the NumPy
# oracle on the materialized expansion — byte-identical across DEDUP
# on/off (raw C-DUP, DEDUP-C correction) and fused/unfused kernel paths.
# ---------------------------------------------------------------------------

Q1_COAUTHOR = """
Nodes(ID, Name) :- Author(ID, Name).
Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
"""

Q2_COPURCHASE = """
Nodes(ID, Name) :- Customer(ID, Name).
Edges(ID1, ID2) :- Orders(ok1, ID1), LineItem(ok1, pk),
                   Orders(ok2, ID2), LineItem(ok2, pk).
"""


def random_catalog_graph(rng: np.random.Generator) -> CondensedGraph:
    """The issue's strategy: a random relational catalog, extracted to a
    condensed graph — single-layer DBLP co-author or 3-layer TPC-H
    co-purchase, with randomized table sizes and skew."""
    seed = int(rng.integers(1_000_000))
    if rng.random() < 0.5:
        cat = dblp_catalog(
            n_authors=int(rng.integers(12, 45)),
            n_pubs=int(rng.integers(15, 70)),
            mean_authors_per_pub=float(rng.uniform(2.0, 5.0)),
            seed=seed,
        )
        dsl = Q1_COAUTHOR
    else:
        cat = tpch_catalog(
            n_customers=int(rng.integers(10, 35)),
            n_orders=int(rng.integers(20, 70)),
            n_parts=int(rng.integers(5, 20)),
            mean_items_per_order=float(rng.uniform(2.0, 4.0)),
            seed=seed,
        )
        dsl = Q2_COPURCHASE
    return extract(cat, dsl, mode="condensed").graph


def _analytics_reps(g):
    """DEDUP off (raw C-DUP) and on (correction), plus the packed kernel
    path with the DEDUP-C epilogue fused and unfused."""
    corr = dedup.build_correction(g)
    return corr, {
        "C-DUP": engine.to_device(g),
        "DEDUP-C": engine.to_device(g, correction=corr),
        "PACKED-fused": engine.to_device_packed(
            g, correction=corr, backend="pallas"
        ),
        "PACKED-unfused": engine.to_device_packed(
            g, correction=corr, backend="pallas", fuse_correction=False
        ),
    }


def _check_analytics_match_oracle(seed: int) -> None:
    rng = np.random.default_rng(seed)
    g = random_catalog_graph(rng)
    A = dense_adjacency(g)
    corr, reps = _analytics_reps(g)
    sources = rng.integers(0, g.n_real, size=3)

    # SCC labels: identical across every representation and DEDUP mode
    lab_ref = scc_labels_ref(A)
    for name, rep in reps.items():
        assert np.array_equal(algorithms.scc_labels(rep, batch=8), lab_ref), name

    # min-plus hop distances (idempotent: exact on raw C-DUP too)
    d_ref = shortest_paths_ref(np.where(A > 0, 1.0, np.inf), sources)
    for name, rep in reps.items():
        d = np.asarray(algorithms.shortest_paths_multi(rep, jnp.asarray(sources)))
        assert np.array_equal(d, d_ref), name

    # triangles: ring propagation — needs DEDUP; per-step (linear DEDUP-C
    # twice) and wedge (quadratic correction, raw hops) must both be
    # byte-identical to the oracle, on segment and packed paths alike
    t_ref = triangle_counts_ref(A)
    wedge = dedup.build_wedge_correction(g, correction=corr)
    for name in ("DEDUP-C", "PACKED-fused", "PACKED-unfused"):
        for kw in (dict(mode="per_step"), dict(mode="wedge"), dict(wedge=wedge)):
            t = algorithms.triangle_counts(reps[name], block=32, **kw)
            assert np.array_equal(t, t_ref), (name, kw)


@pytest.mark.tier2
@given(seed=st.integers(0, 100_000))
@settings(max_examples=15, deadline=None)
def test_analytics_match_dense_oracle(seed):
    _check_analytics_match_oracle(seed)


@pytest.mark.parametrize("seed", [0, 8, 77])
def test_analytics_match_dense_oracle_offline(seed):
    _check_analytics_match_oracle(seed)


def _check_weighted_semirings_match_oracle(seed: int) -> None:
    """Per-virtual-layer weights: min-plus costs and max-min capacities
    on the condensed chains equal dense Bellman-Ford over the
    path-enumerated edge matrix."""
    rng = np.random.default_rng(seed)
    g = random_catalog_graph(rng)
    corr, reps = _analytics_reps(g)
    sources = rng.integers(0, g.n_real, size=3)
    lw = tuple(
        tuple(
            rng.integers(1, 6, size=s).astype(np.float32)
            for s in ch.layer_sizes
        )
        for ch in g.chains
    )
    d_ref = shortest_paths_ref(
        weighted_dense_ref(g, lw, kind="min_plus"), sources
    )
    w_ref = widest_paths_ref(
        weighted_dense_ref(g, lw, kind="max_min"), sources
    )
    for name, rep in reps.items():
        d = np.asarray(
            algorithms.shortest_paths_multi(
                rep, jnp.asarray(sources), layer_weights=lw
            )
        )
        assert np.array_equal(d, d_ref), name
        w = np.asarray(
            algorithms.widest_paths_multi(
                rep, jnp.asarray(sources), layer_capacities=lw
            )
        )
        assert np.array_equal(w, w_ref), name
        # looped single-source oracle == batched columns
        for j, s in enumerate(sources.tolist()):
            ds = np.asarray(
                algorithms.shortest_paths(rep, s, layer_weights=lw)
            )
            assert np.array_equal(ds, d[:, j]), (name, s)


@pytest.mark.tier2
@given(seed=st.integers(0, 100_000))
@settings(max_examples=10, deadline=None)
def test_weighted_semirings_match_dense_oracle(seed):
    _check_weighted_semirings_match_oracle(seed)


@pytest.mark.parametrize("seed", [1, 13])
def test_weighted_semirings_match_dense_oracle_offline(seed):
    _check_weighted_semirings_match_oracle(seed)


# ---------------------------------------------------------------------------
# Budget accounting: the streamed build never holds more than the budget
# of expanded triples, on a graph whose full expansion exceeds it.
# ---------------------------------------------------------------------------

def high_duplication_graph(
    n_real: int = 300, n_virtual: int = 40, size: int = 80, seed: int = 9
) -> CondensedGraph:
    """Heavily overlapping membership sets: raw expanded paths greatly
    exceed the unique-pair count (high duplication ratio)."""
    rng = np.random.default_rng(seed)
    sets = [
        set(rng.choice(n_real, size=size, replace=False).tolist())
        for _ in range(n_virtual)
    ]
    return dedup.graph_from_membership(n_real, sets)


def test_streaming_budget_bounds_peak_residency():
    g = high_duplication_graph()
    n_paths = g.n_paths_expanded()
    n_unique = g.n_edges_expanded()
    budget = 3 * n_unique  # fits the correction, not the expansion
    assert n_paths > budget, "graph must expand past the budget"
    corr = dedup.build_correction_streaming(g, budget_triples=budget)
    acct = corr.accounting
    assert acct.n_paths == n_paths
    assert acct.n_overflow_chunks == 0
    assert acct.peak_resident_triples <= budget
    assert acct.n_merges >= 1
    _assert_same_triples(tuple(dedup.build_correction(g)), tuple(corr))


def test_expansion_accounting_counts():
    rng = np.random.default_rng(4)
    g = random_condensed(rng)
    acct = ExpansionAccounting()
    s, d, m = g.multiplicities(chunk_rows=2, accounting=acct)
    assert acct.n_paths == int(m.sum()) == g.n_paths_expanded()
    assert acct.n_triples_out >= s.size
    assert acct.peak_resident_triples >= s.size


def test_streamed_correction_unpacks_like_tuple():
    g = high_duplication_graph(n_real=40, n_virtual=5, size=12, seed=1)
    corr = dedup.build_correction_streaming(g)
    cs, cd, cm = corr
    assert len(corr) == 3 and corr.nnz == cs.size
    assert corr.nbytes() == cs.nbytes + cd.nbytes + cm.nbytes
