"""Shared dense-expansion differential oracle (ISSUE 9, DESIGN.md §11).

Every condensation-native algorithm in :mod:`repro.core.algorithms` is
checked against a NumPy reference that works on the *expanded* dense
adjacency matrix: expand the condensed graph via
:meth:`CondensedGraph.expand`, materialize ``A`` (or the multiplicity
matrix ``M``), and run a brute-force implementation with no JAX, no
semiring machinery, and no condensed representation anywhere — so a bug
in the engine/dedup/kernels stack cannot cancel out of both sides.

All references are deliberately naive (dense fixpoints, path
enumeration); they are oracles, not implementations.  Tests import this
module directly (``from oracle import ...`` — tests/ is on sys.path via
conftest).
"""
from __future__ import annotations

import numpy as np

from repro.core.condensed import CondensedGraph, ExpandedGraph

__all__ = [
    "dense_multiplicity",
    "dense_adjacency",
    "bipartite_semiring_ref",
    "propagate_ref",
    "bfs_ref",
    "reachable_ref",
    "connected_components_ref",
    "common_neighbors_ref",
    "scc_labels_ref",
    "condensation_ref",
    "triangle_counts_ref",
    "clustering_coefficients_ref",
    "shortest_paths_ref",
    "widest_paths_ref",
    "weighted_dense_ref",
]


# ---------------------------------------------------------------------------
# Expansion: condensed -> dense matrices
# ---------------------------------------------------------------------------

def _expanded(graph) -> ExpandedGraph:
    if isinstance(graph, CondensedGraph):
        return graph.expand()
    if isinstance(graph, ExpandedGraph):
        return graph
    raise TypeError(f"cannot expand {type(graph).__name__}")


def dense_multiplicity(graph, drop_self_loops: bool = True) -> np.ndarray:
    """Dense path-multiplicity matrix ``M`` (int64) of the expanded graph."""
    exp = _expanded(graph)
    if drop_self_loops:
        exp = exp.without_self_loops()
    return exp.adjacency_multiplicity()


def dense_adjacency(graph, drop_self_loops: bool = True) -> np.ndarray:
    """Dense simple 0/1 adjacency ``A = min(M, 1)`` (float64)."""
    return np.minimum(dense_multiplicity(graph, drop_self_loops), 1).astype(
        np.float64
    )


# ---------------------------------------------------------------------------
# Single-layer semiring SpMM reference (the kernel-level oracle)
# ---------------------------------------------------------------------------

def bipartite_semiring_ref(edges, x, semiring, reverse: bool = False):
    """Dense NumPy y[d] = ⊕_{(s,d)∈E} x[s] for one bipartite layer —
    the pure-NumPy twin of ``repro.kernels.ref.segment_semiring_ref``,
    with no JAX segment ops anywhere."""
    src = np.asarray(edges.dst if reverse else edges.src)
    dst = np.asarray(edges.src if reverse else edges.dst)
    n_out = edges.n_src if reverse else edges.n_dst
    x = np.asarray(x, dtype=np.float64)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    y = np.full((n_out, x.shape[1]), float(semiring.zero), dtype=np.float64)
    if semiring.add_kind == "sum":
        np.add.at(y, dst, x[src])
    elif semiring.add_kind == "min":
        np.minimum.at(y, dst, x[src])
    elif semiring.add_kind == "max":
        np.maximum.at(y, dst, x[src])
    else:  # pragma: no cover - unknown semiring
        raise ValueError(semiring.add_kind)
    return y[:, 0] if squeeze else y


def propagate_ref(A: np.ndarray, x: np.ndarray, semiring, reverse=False):
    """Dense one-hop y[w] = ⊕_{u→w} x[u] ⊗ A[u,w] (the engine's Aᵀx
    orientation) over an explicit adjacency matrix."""
    T = A if reverse else A.T
    x = np.asarray(x, dtype=np.float64)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    if semiring.add_kind == "sum":
        y = T @ x
    else:
        mask = T > 0
        vals = np.where(mask[:, :, None], x[None, :, :], float(semiring.zero))
        red = np.min if semiring.add_kind == "min" else np.max
        y = red(vals, axis=1) if mask.any() else np.full(
            (T.shape[0], x.shape[1]), float(semiring.zero)
        )
    return y[:, 0] if squeeze else y


# ---------------------------------------------------------------------------
# Traversal references
# ---------------------------------------------------------------------------

def bfs_ref(A: np.ndarray, sources) -> np.ndarray:
    """(n, B) hop distances (inf where unreachable) by frontier BFS."""
    n = A.shape[0]
    sources = np.atleast_1d(np.asarray(sources))
    D = np.full((n, sources.size), np.inf)
    for j, s in enumerate(sources.tolist()):
        dist = D[:, j]
        dist[s] = 0.0
        frontier = {int(s)}
        hops = 0
        while frontier:
            hops += 1
            nxt = set()
            for u in frontier:
                for v in np.flatnonzero(A[u]):
                    if dist[v] == np.inf:
                        dist[v] = hops
                        nxt.add(int(v))
            frontier = nxt
    return D


def reachable_ref(A: np.ndarray, sources, reverse: bool = False) -> np.ndarray:
    """(n, B) {0,1} reachability (source marked reachable from itself)."""
    D = bfs_ref(A.T if reverse else A, sources)
    return np.isfinite(D).astype(np.float64)


def connected_components_ref(A: np.ndarray, undirected: bool = True):
    """Component label = min member id; symmetrizes unless told not to
    (in which case it is forward-reachability labeling, the old buggy
    directed semantics — kept so the regression test can show the two
    genuinely differ on an asymmetric fixture)."""
    S = np.maximum(A, A.T) if undirected else A
    n = A.shape[0]
    labels = np.arange(n, dtype=np.float64)
    for _ in range(n):
        nxt = labels.copy()
        for u, v in zip(*np.nonzero(S)):
            nxt[v] = min(nxt[v], labels[u])
        if np.array_equal(nxt, labels):
            break
        labels = nxt
    return labels


def common_neighbors_ref(M: np.ndarray, nodes) -> np.ndarray:
    """(n, B) multiplicity-weighted common-neighbor counts: row ``s`` of
    the dense multiplicity matrix per queried node."""
    nodes = np.atleast_1d(np.asarray(nodes))
    return M[nodes].T.astype(np.float64)


# ---------------------------------------------------------------------------
# SCC / condensation references
# ---------------------------------------------------------------------------

def _closure(A: np.ndarray) -> np.ndarray:
    R = np.eye(A.shape[0], dtype=bool) | (A > 0)
    while True:
        nxt = R | (R @ R)
        if np.array_equal(nxt, R):
            return R
        R = nxt


def scc_labels_ref(A: np.ndarray) -> np.ndarray:
    """SCC label per node = min member id, via transitive closure."""
    R = _closure(A)
    same = R & R.T
    return np.array(
        [np.flatnonzero(same[i])[0] for i in range(A.shape[0])], dtype=np.int64
    )


def condensation_ref(A: np.ndarray):
    """(labels, component, sizes, dag edge set, layers) of the SCC DAG;
    layers = longest path to a sink, computed by brute relaxation."""
    labels = scc_labels_ref(A)
    uniq, comp = np.unique(labels, return_inverse=True)
    k = uniq.size
    sizes = np.bincount(comp, minlength=k)
    dag = set()
    for u, v in zip(*np.nonzero(A)):
        if comp[u] != comp[v]:
            dag.add((int(comp[u]), int(comp[v])))
    layers = np.zeros(k, dtype=np.int64)
    for _ in range(k + 1):
        nxt = np.zeros(k, dtype=np.int64)
        for s, d in dag:
            nxt[s] = max(nxt[s], layers[d] + 1)
        if np.array_equal(nxt, layers):
            break
        layers = nxt
    return labels, comp, sizes, dag, layers


# ---------------------------------------------------------------------------
# Triangle / clustering references
# ---------------------------------------------------------------------------

def triangle_counts_ref(A: np.ndarray) -> np.ndarray:
    """t[v] = ½ Σ_w A[v,w]·(A²)[v,w] on a symmetric simple adjacency."""
    return 0.5 * np.sum(A * (A @ A), axis=1)


def clustering_coefficients_ref(A: np.ndarray) -> np.ndarray:
    t = triangle_counts_ref(A)
    deg = A.sum(axis=1)
    denom = deg * (deg - 1.0)
    return np.where(denom > 0, 2.0 * t / np.maximum(denom, 1.0), 0.0)


# ---------------------------------------------------------------------------
# Weighted path references (min-plus / max-min)
# ---------------------------------------------------------------------------

def shortest_paths_ref(W: np.ndarray, sources) -> np.ndarray:
    """(n, B) min-plus distances by Bellman-Ford over a dense edge-cost
    matrix ``W`` (inf = no edge).  For unweighted hop counting pass
    ``np.where(A > 0, 1.0, np.inf)``."""
    n = W.shape[0]
    sources = np.atleast_1d(np.asarray(sources))
    D = np.full((n, sources.size), np.inf)
    D[sources, np.arange(sources.size)] = 0.0
    for _ in range(n):
        relaxed = np.min(D[:, None, :] + W[:, :, None], axis=0)
        nxt = np.minimum(D, relaxed)
        if np.array_equal(nxt, D):
            break
        D = nxt
    return D


def widest_paths_ref(C: np.ndarray, sources) -> np.ndarray:
    """(n, B) max-min path widths over a dense edge-capacity matrix ``C``
    (0 = no edge); sources get width inf."""
    n = C.shape[0]
    sources = np.atleast_1d(np.asarray(sources))
    W = np.zeros((n, sources.size))
    W[sources, np.arange(sources.size)] = np.inf
    for _ in range(n):
        relaxed = np.max(
            np.minimum(W[:, None, :], C[:, :, None]), axis=0
        )
        nxt = np.maximum(W, relaxed)
        if np.array_equal(nxt, W):
            break
        W = nxt
    return W


def weighted_dense_ref(
    graph: CondensedGraph, layer_weights, kind: str = "min_plus"
) -> np.ndarray:
    """Dense per-edge cost (``min_plus``) or capacity (``max_min``)
    matrix of a condensed graph whose virtual layers carry weights.

    Enumerates each chain level-by-level with dense semiring matrix
    products: the cost of a condensed edge u→w is the ⊗-product of the
    virtual-node weights along the best path u→…→w, exactly the quantity
    ``propagate(..., layer_weights=...)`` computes one hop of.  Direct
    edges and self-loops follow the engine's conventions (direct =
    weight identity; self-loops dropped).
    """
    n = graph.n_real
    if kind == "min_plus":
        zero, better = np.inf, np.minimum
        apply_w = lambda T, w: T + w[None, :]
    elif kind == "max_min":
        zero, better = 0.0, np.maximum
        apply_w = lambda T, w: np.minimum(T, w[None, :])
    else:
        raise ValueError(kind)

    def level_dense(e, n_src, n_dst):
        B = np.full((n_src, n_dst), zero)
        one = 0.0 if kind == "min_plus" else np.inf
        B[np.asarray(e.src), np.asarray(e.dst)] = one
        return B

    def semiring_matmul(T, B):
        # (a, b) ⊗ (b, c) with ⊕ = better over the middle axis
        if kind == "min_plus":
            return np.min(T[:, :, None] + B[None, :, :], axis=1)
        return np.max(np.minimum(T[:, :, None], B[None, :, :]), axis=1)

    W = np.full((n, n), zero)
    layer_weights = tuple(layer_weights) if layer_weights is not None else None
    for ci, chain in enumerate(graph.chains):
        sizes = [n] + list(chain.layer_sizes) + [n]
        T = None
        for li, e in enumerate(chain.edges):
            B = level_dense(e, sizes[li], sizes[li + 1])
            T = B if T is None else semiring_matmul(T, B)
            if layer_weights is not None and li < len(chain.edges) - 1:
                w = np.asarray(layer_weights[ci][li], dtype=np.float64)
                T = apply_w(T, w)
        W = better(W, T)
    if graph.direct is not None:
        e = graph.direct
        one = 0.0 if kind == "min_plus" else np.inf
        D = np.full((n, n), zero)
        D[np.asarray(e.src), np.asarray(e.dst)] = one
        W = better(W, D)
    np.fill_diagonal(W, zero)
    return W
