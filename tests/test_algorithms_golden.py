"""Seeded golden regressions for the condensation-native analytics
(DESIGN.md §11): SCC component counts, triangle totals, and distance
histograms pinned on the DBLP and TPC-H extraction fixtures (the paper's
running examples) plus an asymmetric layered fixture for the directed
algorithms — so refactors of the correction algebra / semiring layer
can't silently drift.  Every pinned value was cross-checked against the
dense-expansion oracle (tests/oracle.py) when recorded; the oracle
assertions stay in the tests so a drift is reported as "disagrees with
the dense expansion", not just "differs from a magic number".
"""
import numpy as np
import pytest

import jax.numpy as jnp

from oracle import (
    connected_components_ref,
    dense_adjacency,
    scc_labels_ref,
    triangle_counts_ref,
)

from repro.core import algorithms, dedup, engine
from repro.core.extract import extract
from repro.data.synth import dblp_catalog, layered_condensed, tpch_catalog

Q1_COAUTHOR = """
Nodes(ID, Name) :- Author(ID, Name).
Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
"""

Q2_COPURCHASE = """
Nodes(ID, Name) :- Customer(ID, Name).
Edges(ID1, ID2) :- Orders(ok1, ID1), LineItem(ok1, pk),
                   Orders(ok2, ID2), LineItem(ok2, pk).
"""

# (fixture builder, goldens) — distance histogram counts hops 0..7 over
# sources [0, 1, 2, 3]; triangle total = sum(t)/3 as an exact integer.
GOLDEN = {
    "dblp": dict(
        n_real=400,
        n_components=3,
        largest_component=398,
        triangle_total=6_767_989,
        distance_histogram=[4, 1540, 48, 0, 0, 0, 0, 0],
        n_unreachable=8,
    ),
    "tpch": dict(
        n_real=200,
        n_components=4,
        largest_component=197,
        triangle_total=809_775,
        distance_histogram=[4, 527, 257, 0, 0, 0, 0, 0],
        n_unreachable=12,
    ),
}


def _fixture(name):
    if name == "dblp":
        cat = dblp_catalog(
            n_authors=400, n_pubs=700, mean_authors_per_pub=6.0, seed=1
        )
        return extract(cat, Q1_COAUTHOR, mode="condensed").graph
    cat = tpch_catalog(n_customers=200, n_orders=800, n_parts=60, seed=2)
    return extract(cat, Q2_COPURCHASE, mode="condensed").graph


@pytest.fixture(scope="module", params=sorted(GOLDEN))
def fixture_graph(request):
    g = _fixture(request.param)
    corr = dedup.build_correction(g)
    return request.param, g, engine.to_device(g, correction=corr)


def test_scc_component_goldens(fixture_graph):
    name, g, dev = fixture_graph
    want = GOLDEN[name]
    assert g.n_real == want["n_real"]
    labels = algorithms.scc_labels(dev, batch=32)
    cond = algorithms.condensation(dev, labels=labels)
    assert cond.n_components == want["n_components"]
    assert int(cond.sizes.max()) == want["largest_component"]
    assert int(cond.sizes.sum()) == want["n_real"]
    # both fixtures are co-occurrence (symmetric) graphs: every SCC is a
    # weak component and the condensation DAG has no edges
    assert cond.dag_src.size == 0 and int(cond.layers.max()) == 0
    assert np.array_equal(
        labels,
        np.asarray(algorithms.connected_components(dev)).astype(labels.dtype),
    )


def test_triangle_total_goldens(fixture_graph):
    name, g, dev = fixture_graph
    t = algorithms.triangle_counts(dev, block=128, mode="wedge")
    total = t.sum() / 3.0
    assert float(total).is_integer()
    assert int(total) == GOLDEN[name]["triangle_total"]
    # byte-identical across correction modes
    assert np.array_equal(t, algorithms.triangle_counts(dev, block=128))
    wedge = dedup.build_wedge_correction(g)
    assert np.array_equal(
        t, algorithms.triangle_counts(dev, block=128, wedge=wedge)
    )


def test_distance_histogram_goldens(fixture_graph):
    name, g, dev = fixture_graph
    want = GOLDEN[name]
    dist = np.asarray(
        algorithms.shortest_paths_multi(dev, jnp.asarray([0, 1, 2, 3]))
    )
    finite = dist[np.isfinite(dist)].astype(np.int64)
    hist = np.bincount(finite, minlength=8)[:8]
    assert hist.tolist() == want["distance_histogram"]
    assert int(np.isinf(dist).sum()) == want["n_unreachable"]


# ---------------------------------------------------------------------------
# Directed goldens: an asymmetric layered fixture with a real condensation
# DAG, plus the `connected_components(undirected=...)` regression.
# ---------------------------------------------------------------------------

def _asymmetric_fixture():
    # seed chosen so the graph is weakly but NOT strongly connected:
    # forward-only labeling genuinely diverges from symmetrized labeling
    return layered_condensed(20, [6], [8, 8], seed=1, symmetric=False)


def test_directed_scc_and_layering_goldens():
    g = _asymmetric_fixture()
    A = dense_adjacency(g)
    assert not np.array_equal(A, A.T), "fixture must be asymmetric"
    dev = engine.to_device(g, correction=dedup.build_correction(g))
    labels = algorithms.scc_labels(dev, batch=8)
    assert np.array_equal(labels, scc_labels_ref(A))
    cond = algorithms.condensation(dev, labels=labels)
    assert cond.n_components == 19
    assert int(cond.sizes.max()) == 2
    assert int(cond.layers.max()) == 5
    assert cond.dag_src.size == 41
    # layering invariant: every DAG edge points strictly downward
    assert (cond.layers[cond.dag_src] > cond.layers[cond.dag_dst]).all()


def test_connected_components_undirected_regression():
    """`connected_components` used to propagate labels forward only —
    on an asymmetric fixture that splits one weak component into many
    labels.  `undirected=True` (default) must symmetrize via the packed
    reverse operands and agree with the dense oracle."""
    g = _asymmetric_fixture()
    A = dense_adjacency(g)
    dev = engine.to_device(g)
    cc_u = np.asarray(algorithms.connected_components(dev, undirected=True))
    cc_d = np.asarray(algorithms.connected_components(dev, undirected=False))
    assert np.array_equal(
        cc_u.astype(np.float64), connected_components_ref(A, undirected=True)
    )
    # the fixture is weakly connected: one component, labeled by node 0
    assert np.unique(cc_u).size == 1 and cc_u[0] == 0
    # the old directed semantics fracture it — the regression this pins
    assert np.unique(cc_d).size == 5
    assert not np.array_equal(cc_u, cc_d)
    # default flag value is the fix
    assert np.array_equal(np.asarray(algorithms.connected_components(dev)), cc_u)
    # packed representation takes the same reverse path
    packed = engine.to_device_packed(
        g, correction=dedup.build_correction(g), backend="pallas"
    )
    assert np.array_equal(
        np.asarray(algorithms.connected_components(packed, undirected=True)),
        cc_u,
    )


def test_triangle_goldens_stable_across_backends():
    """The DBLP triangle vector is byte-identical on the packed Pallas
    path (fused and unfused DEDUP-C epilogue) — kernel backends cannot
    perturb the correction algebra."""
    g = _fixture("dblp")
    corr = dedup.build_correction(g)
    t_ref = algorithms.triangle_counts(
        engine.to_device(g, correction=corr), block=128
    )
    for fuse in (True, False):
        packed = engine.to_device_packed(
            g, correction=corr, backend="pallas", fuse_correction=fuse
        )
        t = algorithms.triangle_counts(packed, block=128, mode="wedge")
        assert np.array_equal(t, t_ref), f"fuse_correction={fuse}"
    assert int(t_ref.sum() / 3) == GOLDEN["dblp"]["triangle_total"]
