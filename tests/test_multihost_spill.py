"""Multi-host tree-reduce merge over spilled shards (DESIGN.md §8).

The container is single-process, so the multi-host reduce is exercised
by *simulating* P processes: one ``MultihostSpillExtraction`` per
simulated ``process_index`` against a shared spill directory, phases
driven in lockstep with a no-op barrier — exactly equivalent to the real
thing because every cross-process data dependency flows through spill
records at a phase boundary.  Every process must end with a
``CondensedGraph`` byte-identical to the unsharded single-host build,
including ragged shard-to-process divisions and ``n_shards <
n_processes`` (trailing processes own no shards and sit out the reduce).
"""
import numpy as np
import pytest

from repro.core import extract, graphs_identical
from repro.data.synth import dblp_catalog, univ_catalog
from repro.distributed.sharding import (
    MultihostSpillExtraction,
    extraction_shard_range,
    merge_schedule,
)

Q_DBLP = """
Nodes(ID, Name) :- Author(ID, Name).
Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
"""
Q_UNIV = """
Nodes(ID, Name) :- Instructor(ID, Name).
Nodes(ID, Name) :- Student(ID, Name).
Edges(ID1, ID2) :- TaughtCourse(ID1, courseId), TookCourse(ID2, courseId).
"""


@pytest.fixture(scope="module")
def dblp():
    return dblp_catalog(n_authors=151, n_pubs=301, mean_authors_per_pub=4.0, seed=5)


def _simulate(catalog, query, n_shards, P, spill_dir, **kw):
    """Drive P simulated processes phase-by-phase over one spill dir."""
    procs = [
        MultihostSpillExtraction(
            catalog, query, n_shards, spill_dir,
            process_index=p, process_count=P,
            barrier=lambda name: None, **kw,
        )
        for p in range(P)
    ]
    for m in procs:
        m.phase_nodes()
    for m in procs:
        m.phase_shards()
    for r in range(len(procs[0].schedule)):
        for m in procs:
            m.phase_merge_round(r)
    return [m.phase_finish() for m in procs]


# -- schedule / shard-range composition ---------------------------------------

def test_merge_schedule_log_depth_and_coverage():
    import math

    for n in (1, 2, 3, 5, 7, 8, 13):
        rounds = merge_schedule(n)
        assert len(rounds) == (0 if n <= 1 else math.ceil(math.log2(n)))
        # every non-root partial is absorbed exactly once, into a lower index
        absorbed = [src for rnd in rounds for _, src in rnd]
        assert sorted(absorbed) == list(range(1, n))
        for rnd in rounds:
            for dst, src in rnd:
                assert dst < src
        # and the reduce always lands at index 0
        survivors = set(range(n)) - set(absorbed)
        assert survivors == {0} or n == 0


def test_merge_schedule_pairs_adjacent_ranges():
    """Each merge must join two contiguous, adjacent accumulated shard
    ranges — the order invariant byte-identity rests on."""
    for n in (2, 3, 5, 8):
        spans = {p: (p, p + 1) for p in range(n)}  # accumulated [lo, hi)
        for rnd in merge_schedule(n):
            for dst, src in rnd:
                assert spans[dst][1] == spans[src][0], (n, dst, src)
                spans[dst] = (spans[dst][0], spans[src][1])
        assert spans[0] == (0, n)


def test_extraction_shard_range_composes_with_premerge():
    """Ranges are contiguous, ascending, cover every shard, and empty
    exactly for trailing processes when n_shards < n_processes."""
    for n_shards, procs in [(10, 4), (3, 8), (16, 1), (5, 5), (1, 6), (7, 3)]:
        ranges = [extraction_shard_range(n_shards, p, procs) for p in range(procs)]
        flat = [s for r in ranges for s in r]
        assert flat == list(range(n_shards))
        lo = 0
        for r in ranges:
            assert list(r) == list(range(lo, lo + len(r)))
            lo += len(r)
        active = [p for p, r in enumerate(ranges) if len(r)]
        assert active == list(range(min(n_shards, procs)))


# -- multi-host parity --------------------------------------------------------

@pytest.mark.parametrize("P,n_shards", [(1, 4), (2, 7), (3, 7), (4, 2), (5, 3)])
def test_multihost_byte_identical_on_every_process(dblp, tmp_path, P, n_shards):
    base = extract(dblp, Q_DBLP)
    results = _simulate(dblp, Q_DBLP, n_shards, P, str(tmp_path / "spill"))
    assert len(results) == P
    for res in results:
        assert graphs_identical(base.graph, res.graph)
        assert np.array_equal(base.nodes.keys, res.nodes.keys)
        assert res.dropped_endpoints == base.dropped_endpoints
        assert res.n_shards == n_shards


def test_multihost_heterogeneous_with_props(tmp_path):
    cat = univ_catalog(seed=13)
    base = extract(cat, Q_UNIV)
    results = _simulate(cat, Q_UNIV, 5, 3, str(tmp_path / "spill"))
    for res in results:
        assert graphs_identical(base.graph, res.graph)
        assert np.array_equal(
            base.graph.node_properties["Name"],
            res.graph.node_properties["Name"],
        )


def test_multihost_finalized_spill_is_remergeable(dblp, tmp_path):
    """The root process finalizes the manifest, so the directory a
    multi-host run leaves behind is a valid merge_spilled_graph input."""
    from repro.core import merge_spilled_graph

    base = extract(dblp, Q_DBLP)
    sp = str(tmp_path / "spill")
    _simulate(dblp, Q_DBLP, 6, 3, sp)
    graph, nodes = merge_spilled_graph(sp)
    assert graphs_identical(base.graph, graph)


def test_multihost_run_single_process_fallback(dblp, tmp_path):
    """run() with process_count=1 (the CPU container): no barriers, full
    shard range, same bytes."""
    base = extract(dblp, Q_DBLP)
    res = MultihostSpillExtraction(
        dblp, Q_DBLP, 4, str(tmp_path / "spill"),
        process_index=0, process_count=1,
    ).run()
    assert graphs_identical(base.graph, res.graph)
    assert res.budget.spilled_bytes > 0


def test_multihost_only_active_processes_spill_shards(dblp, tmp_path):
    """n_shards < n_processes: trailing processes own no shard records
    but still reconstruct the identical graph."""
    base = extract(dblp, Q_DBLP)
    P, n_shards = 5, 2
    sp = str(tmp_path / "spill")
    results = _simulate(dblp, Q_DBLP, n_shards, P, sp)
    from repro.core import ShardSpillStore

    store = ShardSpillStore(sp, create=False)
    shard_records = [n for n in store.list_records() if n.startswith("shard_s")]
    assert len(shard_records) == n_shards
    partials = [n for n in store.list_records() if n.startswith("partial_p")]
    assert len(partials) == min(P, n_shards)
    for res in results:
        assert graphs_identical(base.graph, res.graph)
