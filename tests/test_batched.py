"""Batched multi-source propagation (DESIGN.md §3).

The core contract: ``propagate(graph, X)[:, i] == propagate(graph, X[:, i])``
for every column, on every representation, under ring and idempotent
semirings — so all batched algorithms inherit single-source semantics.

Seeded-parametrize property tests (not hypothesis-based: these must run in
the offline container too).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import random_membership_graph, random_multilayer_graph
from oracle import bfs_ref, common_neighbors_ref, dense_adjacency, dense_multiplicity

from repro.core import algorithms, dedup, engine
from repro.core.semiring import MAX_TIMES, MIN_PLUS, OR_AND, PLUS_TIMES
from repro.serve import GraphQuery, GraphQueryServer

SEEDS = [0, 1, 7, 23]
B = 5


def _graph(seed):
    rng = np.random.default_rng(seed)
    return random_membership_graph(
        int(rng.integers(8, 40)), int(rng.integers(2, 10)), 4, rng
    ), rng


def _exact_reps(g):
    corr = dedup.build_correction(g)
    return {
        "EXP": engine.to_device(g.expand()),
        "DEDUP-C": engine.to_device(g, correction=corr),
        "PACKED": engine.to_device_packed(g, correction=corr, backend="pallas"),
    }


# ---------------------------------------------------------------------------
# Column-equivalence property
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_ring_matrix_propagate_matches_columns(seed):
    """plus-times: (n, B) == B single-vector calls, on every exact rep and
    on raw C-DUP (allow_duplicates), forward and reverse."""
    g, rng = _graph(seed)
    X = rng.standard_normal((g.n_real, B)).astype(np.float32)
    reps = _exact_reps(g)
    for name, rep in reps.items():
        for reverse in (False, True):
            Y = np.asarray(
                engine.propagate(rep, jnp.asarray(X), PLUS_TIMES, reverse=reverse)
            )
            for i in range(B):
                yi = np.asarray(
                    engine.propagate(
                        rep, jnp.asarray(X[:, i]), PLUS_TIMES, reverse=reverse
                    )
                )
                assert np.allclose(Y[:, i], yi, atol=1e-4), (name, reverse, i)
    cdup = engine.to_device(g)
    Y = np.asarray(
        engine.propagate(cdup, jnp.asarray(X), PLUS_TIMES, allow_duplicates=True)
    )
    for i in range(B):
        yi = np.asarray(
            engine.propagate(
                cdup, jnp.asarray(X[:, i]), PLUS_TIMES, allow_duplicates=True
            )
        )
        assert np.allclose(Y[:, i], yi, atol=1e-4), i


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize(
    "semiring", [MIN_PLUS, MAX_TIMES, OR_AND], ids=lambda s: s.name
)
def test_idempotent_matrix_propagate_matches_columns(seed, semiring):
    """Idempotent semirings run on raw C-DUP directly; batched == looped."""
    g, rng = _graph(seed)
    if semiring is MIN_PLUS:
        X = np.where(
            rng.random((g.n_real, B)) < 0.3,
            rng.random((g.n_real, B)),
            np.inf,
        ).astype(np.float32)
    else:
        X = (rng.random((g.n_real, B)) < 0.4).astype(np.float32)
    for rep in (engine.to_device(g), engine.to_device(g.expand())):
        Y = np.asarray(engine.propagate(rep, jnp.asarray(X), semiring))
        for i in range(B):
            yi = np.asarray(engine.propagate(rep, jnp.asarray(X[:, i]), semiring))
            assert np.allclose(Y[:, i], yi), i


@pytest.mark.parametrize("seed", SEEDS[:2])
def test_multilayer_matrix_propagate_matches_columns(seed):
    rng = np.random.default_rng(seed)
    g = random_multilayer_graph(int(rng.integers(10, 25)), [5, 4], 0.2, rng)
    corr = dedup.build_correction(g)
    X = rng.standard_normal((g.n_real, B)).astype(np.float32)
    for rep in (
        engine.to_device(g, correction=corr),
        engine.to_device_packed(g, correction=corr, backend="pallas"),
        engine.to_device(g.expand()),
    ):
        Y = np.asarray(engine.propagate(rep, jnp.asarray(X), PLUS_TIMES))
        for i in range(B):
            yi = np.asarray(engine.propagate(rep, jnp.asarray(X[:, i]), PLUS_TIMES))
            assert np.allclose(Y[:, i], yi, atol=1e-4), i


def test_propagate_rejects_bad_frontier_shapes():
    g, rng = _graph(0)
    rep = engine.to_device(g.expand())
    with pytest.raises(ValueError):
        engine.propagate(rep, jnp.zeros((g.n_real + 1,)), PLUS_TIMES)
    with pytest.raises(ValueError):
        engine.propagate(rep, jnp.zeros((3, g.n_real)), PLUS_TIMES)
    with pytest.raises(ValueError):
        engine.propagate(rep, jnp.zeros((g.n_real, 2, 2)), PLUS_TIMES)


# ---------------------------------------------------------------------------
# Packed representation: kernel path == XLA path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS[:2])
def test_packed_backends_agree(seed):
    g, rng = _graph(seed)
    corr = dedup.build_correction(g)
    X = jnp.asarray(rng.standard_normal((g.n_real, 3)).astype(np.float32))
    y_pl = engine.propagate(
        engine.to_device_packed(g, correction=corr, backend="pallas"), X
    )
    y_xla = engine.propagate(
        engine.to_device_packed(g, correction=corr, backend="xla"), X
    )
    y_ref = engine.propagate(engine.to_device(g, correction=corr), X)
    assert np.allclose(np.asarray(y_pl), np.asarray(y_ref), atol=1e-4)
    assert np.allclose(np.asarray(y_xla), np.asarray(y_ref), atol=1e-4)


# ---------------------------------------------------------------------------
# Batched algorithms == their single-source counterparts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS[:2])
def test_bfs_and_reachable_multi_match_single(seed):
    g, rng = _graph(seed)
    n = g.n_real
    sources = rng.integers(0, n, size=4)
    # dense-expansion differential oracle (tests/oracle.py)
    D_ref = bfs_ref(dense_adjacency(g), sources)
    for rep in (engine.to_device(g), engine.to_device(g.expand())):
        D = np.asarray(algorithms.bfs_multi(rep, jnp.asarray(sources)))
        R = np.asarray(algorithms.reachable_multi(rep, jnp.asarray(sources)))
        assert np.array_equal(D, D_ref)
        assert np.array_equal(R, np.isfinite(D_ref).astype(R.dtype))
        for i, s in enumerate(sources.tolist()):
            assert np.allclose(D[:, i], np.asarray(algorithms.bfs(rep, s))), i
            assert np.allclose(
                R[:, i], np.asarray(algorithms.reachable(rep, s))
            ), i


@pytest.mark.parametrize("seed", SEEDS[:2])
def test_personalized_pagerank_batch_matches_single(seed):
    g, rng = _graph(seed)
    n = g.n_real
    sources = rng.integers(0, n, size=4)
    reps = _exact_reps(g)
    seeds = algorithms.one_hot_frontier(n, jnp.asarray(sources))
    ref = None
    for name, rep in reps.items():
        P = np.asarray(algorithms.personalized_pagerank(rep, seeds, num_iters=12))
        for i in range(len(sources)):
            p1 = np.asarray(
                algorithms.personalized_pagerank(rep, seeds[:, i], num_iters=12)
            )
            assert np.allclose(P[:, i], p1, atol=1e-5), (name, i)
        if ref is None:
            ref = P
        assert np.allclose(P, ref, atol=1e-4), name


def test_common_neighbors_multi_counts_multiplicity():
    rng = np.random.default_rng(3)
    g = random_membership_graph(20, 8, 4, rng)
    rep = engine.to_device(g, drop_self_loops=False)
    M = dense_multiplicity(g, drop_self_loops=False)
    nodes = np.array([0, 5, 11])
    C = np.asarray(algorithms.common_neighbors_multi(rep, jnp.asarray(nodes)))
    assert np.array_equal(C, common_neighbors_ref(M, nodes).astype(C.dtype))


def test_one_hot_frontier_shape_and_values():
    x = np.asarray(algorithms.one_hot_frontier(6, jnp.asarray([2, 2, 5]),
                                               value=0.0, fill=np.inf))
    assert x.shape == (6, 3)
    assert x[2, 0] == 0.0 and x[2, 1] == 0.0 and x[5, 2] == 0.0
    assert np.isinf(x).sum() == 15


# ---------------------------------------------------------------------------
# Serving: queued queries fused into batched propagation calls
# ---------------------------------------------------------------------------

def test_graph_query_server_batches_and_answers():
    rng = np.random.default_rng(9)
    g = random_membership_graph(30, 10, 4, rng)
    corr = dedup.build_correction(g)
    server = GraphQueryServer(
        engine.to_device(g, correction=corr),
        counts_graph=engine.to_device(g, drop_self_loops=False),
        max_batch=4,
    )
    queries = [GraphQuery(i, "bfs", int(i % 30)) for i in range(6)]
    queries += [GraphQuery(50 + i, "ppr", int(3 * i % 30)) for i in range(3)]
    queries += [
        GraphQuery(90 + i, "common_neighbors", int(5 * i % 30)) for i in range(2)
    ]
    answers = server.run(queries)
    # 6 bfs / cap 4 -> 2 batches; 3 ppr -> 1; 2 cn -> 1
    assert server.n_queries == 11
    assert server.n_propagation_batches == 4
    assert set(answers) == {q.qid for q in queries}
    assert np.allclose(
        answers[0], np.asarray(algorithms.bfs(server.graph, 0))
    )
    seeds = np.zeros(30, np.float32)
    seeds[3] = 1.0
    assert np.allclose(
        answers[51],
        np.asarray(
            algorithms.personalized_pagerank(server.graph, jnp.asarray(seeds))
        ),
        atol=1e-6,
    )
    ind = np.zeros(30, np.float32)
    ind[5] = 1.0
    assert np.allclose(
        answers[91],
        np.asarray(
            algorithms.common_neighbor_counts(server.counts_graph, jnp.asarray(ind))
        ),
    )
    with pytest.raises(ValueError):
        server.submit(GraphQuery(999, "triangle_count", 0))
    # out-of-range nodes must be rejected at submit time: JAX scatters
    # silently drop/wrap bad indices, which would serve a wrong answer
    with pytest.raises(ValueError):
        server.submit(GraphQuery(998, "bfs", 30))
    with pytest.raises(ValueError):
        server.submit(GraphQuery(997, "ppr", -1))
    # answers are keyed by qid, so a pending duplicate would be overwritten
    server.submit(GraphQuery(996, "bfs", 1))
    with pytest.raises(ValueError):
        server.submit(GraphQuery(996, "ppr", 2))


def test_graph_query_server_buckets_batch_widths():
    """Flush groups are padded to fixed widths (8/16/32, capped at
    max_batch) so live traffic compiles a handful of propagation shapes
    instead of one per distinct group size — and padding never changes
    the answers."""
    rng = np.random.default_rng(21)
    g = random_membership_graph(30, 10, 4, rng)
    corr = dedup.build_correction(g)
    graph = engine.to_device(g, correction=corr)
    server = GraphQueryServer(graph, max_batch=32)
    assert server.bucket_widths == (8, 16, 32)
    # odd group sizes: 5 bfs -> width 8; 11 ppr -> width 16; 1 cn -> 8
    queries = [GraphQuery(i, "bfs", int(i % 30)) for i in range(5)]
    queries += [GraphQuery(100 + i, "ppr", int(2 * i % 30)) for i in range(11)]
    queries += [GraphQuery(200, "common_neighbors", 7)]
    answers = server.run(queries)
    assert set(server.batch_widths_used) <= set(server.bucket_widths)
    assert server.batch_widths_used == {8: 2, 16: 1}
    # padded columns are sliced off: answers equal the unbatched calls
    assert np.allclose(answers[0], np.asarray(algorithms.bfs(graph, 0)))
    assert len(answers) == len(queries)
    # a tiny max_batch collapses every group to that single width
    small = GraphQueryServer(graph, max_batch=4)
    assert small.bucket_widths == (4,)
    small.run([GraphQuery(i, "bfs", i) for i in range(6)])
    assert small.batch_widths_used == {4: 2}


# ---------------------------------------------------------------------------
# Sharding rules: logical batch axis resolves, engine is mesh-agnostic
# ---------------------------------------------------------------------------

def test_graph_rules_resolve_batch_axis():
    import jax
    from jax.sharding import PartitionSpec

    from repro.distributed import sharding

    mesh = jax.make_mesh((1,), ("data",))
    spec = sharding.logical_spec(
        ["graph_nodes", "graph_batch"], sharding.GRAPH_RULES, mesh
    )
    assert spec == PartitionSpec(None, ("data",))
    # outside any mesh context the annotation is a no-op
    x = jnp.ones((4, 2))
    assert sharding.shard_frontier(x) is x
    with pytest.raises(ValueError):
        sharding.shard_frontier(jnp.ones((2, 2, 2)))


def test_algorithms_run_under_mesh_rules():
    import jax

    from repro.distributed.sharding import GRAPH_RULES, use_mesh_rules

    rng = np.random.default_rng(11)
    g = random_membership_graph(24, 8, 4, rng)
    cdup = engine.to_device(g)
    sources = jnp.asarray([0, 5, 9])
    ref = np.asarray(algorithms.bfs_multi(cdup, sources))
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    with use_mesh_rules(mesh, GRAPH_RULES):
        got = np.asarray(algorithms.bfs_multi(cdup, sources))
    assert np.allclose(got, ref)
