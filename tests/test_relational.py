import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.relational import Catalog, Table, estimate_join_output, hash_join, semi_join


def brute_join(lk, rk):
    out = []
    for i, a in enumerate(lk):
        for j, b in enumerate(rk):
            if a == b:
                out.append((i, j))
    return out


@given(
    lk=st.lists(st.integers(0, 8), min_size=0, max_size=30),
    rk=st.lists(st.integers(0, 8), min_size=0, max_size=30),
)
@settings(max_examples=100, deadline=None)
def test_hash_join_matches_bruteforce(lk, rk):
    left = Table("L", {"k": np.array(lk, dtype=np.int64), "lv": np.arange(len(lk))})
    right = Table("R", {"k": np.array(rk, dtype=np.int64), "rv": np.arange(len(rk))})
    joined = hash_join(left, right, "k", "k")
    got = sorted(zip(joined.column("lv").tolist(), joined.column("rv").tolist()))
    assert got == sorted(brute_join(lk, rk))
    # canonical single key column
    assert "k" in joined.column_names
    assert "k_l" not in joined.column_names


def test_hash_join_different_key_names():
    left = Table("L", {"a": np.array([1, 2, 2]), "x": np.array([0, 1, 2])})
    right = Table("R", {"b": np.array([2, 2, 3]), "y": np.array([5, 6, 7])})
    j = hash_join(left, right, "a", "b")
    assert len(j) == 4
    assert set(j.column_names) == {"a", "x", "b", "y"}


def test_semi_join_and_stats():
    left = Table("L", {"k": np.array([1, 2, 3, 4])})
    right = Table("R", {"k": np.array([2, 4, 4])})
    sj = semi_join(left, right, "k", "k")
    assert sorted(sj.column("k").tolist()) == [2, 4]
    assert right.stats("k").n_distinct == 2
    est = estimate_join_output(left, right, "k", "k")
    assert est == pytest.approx(4 * 3 / 4)


def test_table_validation_and_ops():
    with pytest.raises(ValueError):
        Table("bad", {"a": np.arange(3), "b": np.arange(4)})
    t = Table("T", {"a": np.arange(5), "b": np.arange(5) * 2})
    sel = t.select(lambda c: c["a"] > 2)
    assert len(sel) == 2
    proj = t.project(["b"])
    assert proj.column_names == ["b"]
    cat = Catalog([t])
    assert "t" in cat and cat.table("T") is t
    with pytest.raises(KeyError):
        cat.table("missing")
