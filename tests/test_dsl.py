import pytest

from repro.core.dsl import ParseError, parse


Q1 = """
Nodes(ID, Name) :- Author(ID, Name).
Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
"""

Q2 = """
Nodes(ID, Name) :- Customer(ID, Name).
Edges(ID1, ID2) :- Orders(ok1, ID1), LineItem(ok1, pk),
                   Orders(ok2, ID2), LineItem(ok2, pk).
"""

Q3 = """
Nodes(ID, Name) :- Instructor(ID, Name).
Nodes(ID, Name) :- Student(ID, Name).
Edges(ID1, ID2) :- TaughtCourse(ID1, courseId), TookCourse(ID2, courseId).
"""


def test_parse_q1():
    q = parse(Q1)
    assert len(q.nodes_rules) == 1 and len(q.edges_rules) == 1
    e = q.edges_rules[0]
    assert e.head_vars == ("ID1", "ID2")
    assert [a.relation for a in e.atoms] == ["AuthorPub", "AuthorPub"]
    assert e.atoms[0].args == ("ID1", "PubID")


def test_parse_q2_multiline():
    q = parse(Q2)
    assert len(q.edges_rules[0].atoms) == 4


def test_parse_q3_heterogeneous():
    q = parse(Q3)
    assert q.heterogeneous
    assert [r.atoms[0].relation for r in q.nodes_rules] == ["Instructor", "Student"]


def test_parse_comparisons_and_constants():
    q = parse(
        """
        Nodes(ID) :- Author(ID, _).
        Edges(A, B) :- AP(A, P), Pub(P, y, 'CS'), AP(B, P), y >= 2010.
        """
    )
    e = q.edges_rules[0]
    assert e.comparisons[0].var == "y" and e.comparisons[0].op == ">="
    pub = e.atoms[1]
    assert pub.constants == ((2, "CS"),)
    assert pub.args == ("P", "y", "_")
    assert q.nodes_rules[0].atoms[0].args == ("ID", "_")


def test_parse_comments():
    q = parse(
        """
        # co-author graph
        Nodes(ID) :- Author(ID, _).  % inline
        Edges(A, B) :- AP(A, P), AP(B, P).
        """
    )
    assert len(q.edges_rules) == 1


@pytest.mark.parametrize(
    "bad",
    [
        "Edges(A, B) :- R(A, B).",                      # no Nodes
        "Nodes(ID) :- R(ID).",                          # no Edges
        "Nodes(ID) :- .",                               # empty body
        "Foo(ID) :- R(ID).",                            # bad head
        "Nodes(ID) :- R(ID). Edges(A) :- R(A, B).",     # Edges arity
    ],
)
def test_parse_errors(bad):
    with pytest.raises(ParseError):
        parse(bad)
