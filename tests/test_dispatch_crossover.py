"""Measured-crossover dispatch honesty.

The bug this PR fixes: BENCH_kernels.json showed ``backend_auto``
routing the 20480-source smoke cell to Pallas at a measured 35x loss
(61.2 ms vs 1.7 ms) because dispatch trusted the VMEM footprint formula
alone.  These tests pin the fix at every dispatch site: given a recorded
crossover table, 'auto' NEVER selects a backend the table says is slower
— not in ``ops.resolve_backend``, not in ``ops.bitmap_spmm``, not in
``engine._kernel_applicable`` — and without a table the footprint
fallback behaves exactly as before.
"""
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from conftest import random_bipartite, random_membership_graph

from repro.core import dedup, engine
from repro.core.semiring import MIN_PLUS, PLUS_TIMES, segment_reduce
from repro.kernels.autotune import (
    CrossoverEntry,
    CrossoverTable,
    measure_crossover,
)
from repro.kernels.ops import PackedLayer, resolve_backend


def _table(cells):
    return CrossoverTable.from_entries(
        {k: CrossoverEntry(*v) for k, v in cells.items()}
    )


# The recorded smoke cells from the bench that exposed the bug: Pallas
# measured 35x slower on the large cell, slightly slower on the small one.
BENCH_BUG_TABLE = _table(
    {
        ("sum", 10, 6): (103.0, 57.0),          # n_src=1024, B=64
        ("sum", 15, 6): (61159.0, 1739.0),      # n_src=20480, B=64
    }
)


def test_auto_never_selects_measured_slower_backend():
    # every recorded cell: 'auto' must resolve to the measured winner
    for (op, sb, bb), entry in BENCH_BUG_TABLE.entries:
        n_src, b = 2**sb, 2**bb
        resolved = resolve_backend(
            "auto", b, 128, 4, table=BENCH_BUG_TABLE, n_src=n_src
        )
        assert resolved == entry.backend, (op, sb, bb)
        assert resolved == "xla"  # both bug cells were Pallas losses


def test_footprint_would_have_picked_pallas():
    # the regression scenario: without the table the footprint formula
    # still routes the 35x-loss cell to the kernel — the table must win
    assert resolve_backend("auto", 64, 128, 4) == "pallas"
    assert (
        resolve_backend(
            "auto", 64, 128, 4, table=BENCH_BUG_TABLE, n_src=20480
        )
        == "xla"
    )


def test_measured_pallas_win_dispatches_even_when_unfashionable():
    table = _table({("sum", 15, 6): (120.0, 900.0)})
    assert (
        resolve_backend("auto", 64, 128, 4, table=table, n_src=20480)
        == "pallas"
    )


def test_measured_win_still_respects_vmem_budget():
    # a measured-pallas entry whose recorded config no longer fits the
    # budget must not dispatch blindly
    table = _table({("sum", 12, 6): (10.0, 900.0, 128 * 4096, 128)})
    assert (
        resolve_backend("auto", 64, 128, 4, table=table, n_src=4096) == "xla"
    )


def test_nearest_bucket_fallback_is_deterministic():
    table = BENCH_BUG_TABLE
    # unmeasured sizes snap to the nearest measured bucket, same answer
    # every time and from both ends
    for n_src in (3000, 300_000):
        a = [table.decide("sum", n_src, 64) for _ in range(3)]
        assert a == [a[0]] * 3
    # op never measured -> no opinion (footprint fallback)
    assert table.decide("min", 20480, 64) is None
    assert resolve_backend(
        "auto", 64, 128, 4, semiring=MIN_PLUS, table=table, n_src=20480
    ) == "pallas"


def test_explicit_backends_ignore_table():
    assert resolve_backend(
        "pallas", 64, 128, 4, table=BENCH_BUG_TABLE, n_src=20480
    ) == "pallas"
    assert resolve_backend(
        "xla", 64, 128, 4, table=_table({("sum", 5, 6): (1.0, 9.0)}), n_src=32
    ) == "xla"


def test_layer_carries_table_through_bitmap_spmm():
    rng = np.random.default_rng(0)
    layer = PackedLayer.from_edges(random_bipartite(300, 200, 1200, rng))
    x = jnp.asarray(rng.integers(0, 5, (300, 16)).astype(np.float32))
    want = np.asarray(
        segment_reduce(PLUS_TIMES, x[np.asarray(layer.src)], layer.dst, 200)
    )
    # measured-xla: auto must produce the segment result (and not crash
    # even if the packing were somehow broken for pallas)
    layer.crossover = _table({("sum", 9, 4): (999.0, 1.0)})
    from repro.kernels.ops import bitmap_spmm

    got = np.asarray(bitmap_spmm(layer, x, backend="auto"))
    assert np.array_equal(got, want)
    # measured-pallas: auto dispatches the kernel off-TPU too; results agree
    layer.crossover = _table({("sum", 9, 4): (1.0, 999.0)})
    got_k = np.asarray(bitmap_spmm(layer, x, backend="auto"))
    assert np.array_equal(got_k, want)


def _packed_graph(seed=0, **kwargs):
    rng = np.random.default_rng(seed)
    g = random_membership_graph(150, 30, 5, rng)
    corr = dedup.build_correction(g)
    return g, engine.to_device_packed(g, correction=corr, **kwargs)


def _inject_table(packed, table):
    chains = tuple(
        tuple(
            dataclasses.replace(
                layer,
                fwd=dataclasses.replace(layer.fwd, crossover=table),
                rev=dataclasses.replace(layer.rev, crossover=table),
            )
            for layer in chain
        )
        for chain in packed.chains
    )
    return dataclasses.replace(packed, chains=chains, fused_fwd=None,
                               fused_rev=None)


def test_engine_auto_honors_measured_table():
    _, packed = _packed_graph(backend="auto")
    x = jnp.asarray(
        np.random.default_rng(1).integers(0, 5, (150, 8)).astype(np.float32)
    )
    slow = _inject_table(
        packed, _table({("sum", 8, 3): (5000.0, 10.0)})
    )
    engine.reset_kernel_dispatch_count()
    engine.propagate(slow, x, PLUS_TIMES)
    assert engine.KERNEL_DISPATCH_COUNT == 0  # measured-xla: never Pallas
    fast = _inject_table(
        packed, _table({("sum", 8, 3): (10.0, 5000.0)})
    )
    engine.reset_kernel_dispatch_count()
    engine.propagate(fast, x, PLUS_TIMES)
    assert engine.KERNEL_DISPATCH_COUNT > 0  # measured-pallas: kernel, off-TPU


def test_engine_measured_results_match_unmeasured():
    g, packed = _packed_graph(backend="auto")
    x = jnp.asarray(
        np.random.default_rng(1).integers(0, 5, (150, 8)).astype(np.float32)
    )
    fast = _inject_table(packed, _table({("sum", 8, 3): (10.0, 5000.0)}))
    corr = dedup.build_correction(g)
    want = np.asarray(
        engine.propagate(engine.to_device(g, correction=corr), x, PLUS_TIMES)
    )
    got = np.asarray(engine.propagate(fast, x, PLUS_TIMES))
    assert np.array_equal(got, want)


def test_measure_crossover_records_argmin_decisions():
    rng = np.random.default_rng(2)
    layer = PackedLayer.from_edges(random_bipartite(260, 180, 900, rng))
    ticks = iter(range(1, 1000))
    table = measure_crossover(
        layer,
        batch_sizes=(8, 64),
        time_fn=lambda fn: float(next(ticks)),
    )
    assert len(table) == 2
    for (op, sb, bb), entry in table.entries:
        assert entry.backend == (
            "pallas" if entry.pallas_us <= entry.xla_us else "xla"
        )
        # the decision a dispatcher reads back equals the recorded winner
        n_src, b = 2**sb, 2**bb
        assert table.decide(op, n_src, b) == entry.backend
