import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import expanded_simple_pairs, random_membership_graph, random_multilayer_graph

from repro.core import dedup


def _pairs_with_self(g):
    s, d, _ = g.multiplicities()
    return set(zip(s.tolist(), d.tolist()))


@given(seed=st.integers(0, 100_000))
@settings(max_examples=60, deadline=None)
def test_correction_exactness(seed):
    rng = np.random.default_rng(seed)
    n_layers = int(rng.integers(1, 4))
    if n_layers == 1:
        g = random_membership_graph(int(rng.integers(4, 20)), int(rng.integers(1, 7)), 3, rng)
    else:
        g = random_multilayer_graph(int(rng.integers(4, 10)), [3] * n_layers, 0.3, rng)
    cs, cd, cm = dedup.build_correction(g)
    M = g.expand().adjacency_multiplicity()
    D = np.zeros_like(M)
    np.add.at(D, (cs, cd), cm)
    A = M - D
    want = np.minimum(M, 1)
    np.fill_diagonal(want, 0)
    assert (A == want).all()


@given(seed=st.integers(0, 100_000))
@settings(max_examples=50, deadline=None)
def test_bitmap_algorithms_enumerate_each_pair_once(seed):
    rng = np.random.default_rng(seed)
    g = random_membership_graph(int(rng.integers(4, 25)), int(rng.integers(1, 8)), 4, rng)
    want = _pairs_with_self(g)
    for fn in (dedup.bitmap1, dedup.bitmap2):
        rep = fn(g)
        u, v = rep.to_dedup_pairs()
        pairs = list(zip(u.tolist(), v.tolist()))
        assert len(pairs) == len(set(pairs)), fn.__name__
        assert set(pairs) == want, fn.__name__


def test_bitmap2_deletes_redundant_edges():
    # two virtual nodes with identical membership: set cover keeps one.
    g = dedup.graph_from_membership(6, [{0, 1, 2, 3}, {0, 1, 2, 3}, {4, 5}])
    b2 = dedup.bitmap2(g)
    b1 = dedup.bitmap1(g)
    assert b2.n_bitmaps < b1.n_bitmaps
    assert b2.nbytes() < b1.nbytes()


DEDUP1_FNS = [
    dedup.dedup1_naive_virtual_first,
    dedup.dedup1_naive_real_first,
    dedup.dedup1_greedy_real_first,
    dedup.dedup1_greedy_virtual_first,
]


@pytest.mark.parametrize("fn", DEDUP1_FNS, ids=lambda f: f.__name__)
@given(seed=st.integers(0, 50_000))
@settings(max_examples=25, deadline=None)
def test_dedup1_equivalence_and_uniqueness(fn, seed):
    rng = np.random.default_rng(seed)
    g = random_membership_graph(int(rng.integers(4, 22)), int(rng.integers(1, 7)), 4, rng)
    res = fn(g, rng=np.random.default_rng(seed + 1))
    # same expanded simple graph
    assert expanded_simple_pairs(res.graph) == expanded_simple_pairs(g), fn.__name__
    # multiplicity <= 1 off-diagonal (DEDUP-1 invariant)
    s, d, m = res.graph.multiplicities()
    off = s != d
    assert (m[off] <= 1).all(), fn.__name__
    assert res.total_edges > 0
    assert res.seconds >= 0


@pytest.mark.parametrize("ordering", ["identity", "random"])
@given(seed=st.integers(0, 50_000))
@settings(max_examples=25, deadline=None)
def test_dedup2_invariants(ordering, seed):
    rng = np.random.default_rng(seed)
    g = random_membership_graph(int(rng.integers(4, 22)), int(rng.integers(1, 8)), 4, rng)
    rep = dedup.dedup2_greedy(g, ordering=ordering, rng=np.random.default_rng(seed))
    mult = rep.pair_multiplicities()
    want = {p for p in expanded_simple_pairs(g) if p[0] < p[1]}
    assert set(mult) == want
    assert all(c == 1 for c in mult.values())
    # invariants (1)-(3)
    for i, a in enumerate(rep.sets):
        for j, b in enumerate(rep.sets):
            if i < j and (i, j) not in rep.vv_edges:
                assert len(a & b) <= 1, "invariant 1"
    for i, j in rep.vv_edges:
        assert not (rep.sets[i] & rep.sets[j]), "invariant 2"


def test_dedup2_compresses_overlapping_cliques():
    # Fig 6 scenario: two large overlapping cliques.
    big1 = set(range(0, 12))
    big2 = set(range(6, 18))
    g = dedup.graph_from_membership(20, [big1, big2])
    rep = dedup.dedup2_greedy(g)
    d1 = dedup.dedup1_greedy_virtual_first(g)
    # DEDUP-2 should beat DEDUP-1 here (vv-edges vs direct-edge blowup)
    assert rep.n_edges < d1.total_edges


def test_requires_symmetric_single_layer():
    rng = np.random.default_rng(0)
    g = random_multilayer_graph(6, [3, 3], 0.4, rng)
    with pytest.raises(ValueError):
        dedup.dedup1_greedy_virtual_first(g)
    assert not dedup.is_symmetric_single_layer(g)


@given(seed=st.integers(0, 50_000))
@settings(max_examples=20, deadline=None)
def test_multilayer_collapse_preserves_multiplicities(seed):
    from repro.core.condensed import collapse_to_single_layer

    rng = np.random.default_rng(seed)
    g = random_multilayer_graph(int(rng.integers(4, 10)),
                                [int(rng.integers(2, 5)),
                                 int(rng.integers(2, 5))], 0.35, rng)
    flat = collapse_to_single_layer(g, max_growth=1000.0)
    assert flat.is_single_layer()
    assert (flat.expand().adjacency_multiplicity()
            == g.expand().adjacency_multiplicity()).all()


@given(seed=st.integers(0, 50_000))
@settings(max_examples=20, deadline=None)
def test_multilayer_bitmap_via_collapse(seed):
    """Paper §5.2.2: multi-layer dedup = collapse-to-single-layer +
    single-layer BITMAP; each expanded pair enumerated exactly once."""
    from repro.core.condensed import collapse_to_single_layer

    rng = np.random.default_rng(seed)
    g = random_multilayer_graph(int(rng.integers(4, 9)),
                                [3, int(rng.integers(2, 4))], 0.35, rng)
    flat = collapse_to_single_layer(g, max_growth=1000.0)
    rep = dedup.bitmap2(flat)
    u, v = rep.to_dedup_pairs()
    pairs = list(zip(u.tolist(), v.tolist()))
    s0, d0, _ = g.multiplicities()
    assert len(pairs) == len(set(pairs))
    assert set(pairs) == set(zip(s0.tolist(), d0.tolist()))
