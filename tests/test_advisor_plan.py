"""Plan-oracle suite for the cost-based extraction optimizer (DESIGN.md §12).

Four layers of evidence that ``repro.core.cost.plan`` can be trusted:

1. **Reality check** — per DBLP/TPCH/UNIV fixture the chosen plan is
   executed against every hand-picked config the extraction bench
   commits (``sharded{1,2,7}``, ``spill{2,7}`` rows) and must not lose
   on wall time, and every plan the optimizer ranks as feasible must
   produce a byte-identical graph with measured peaks within the
   predicted bounds.
2. **Properties** (hypothesis ``@given`` + seeded ``_offline`` twins,
   tier-2 oracle gate): predicted peak bounds are monotone
   nondecreasing in table rows and nonincreasing in ``n_shards``; a
   budget-feasible plan never raises ``ExtractionBudgetError``; plan
   choice is deterministic for a fixed catalog.
3. **Golden reports** — the rendered markdown report and the canonical
   JSON round-trip are pinned for two fixtures (same contract as
   tests/test_crossover_golden.py: a silent policy change must fail
   loudly here).
4. **Crossover routing** — a measured-slower Pallas cell flips the
   advisor's device recommendation from DEDUP-C to EXP, and an
   all-XLA table makes the planner prune fused-correction configs.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    Catalog,
    ExtractionBudget,
    Table,
    extract,
    graphs_identical,
    plan,
)
from repro.core.advisor import recommend
from repro.core.cost import (
    PlanConfig,
    PlanReport,
    Throughputs,
    assembly_account_bounds,
    peak_resident_rows_bound,
    peak_transient_bytes_bound,
    plan_cost,
    profile_query,
)
from repro.core.serialize import load_plan_report, save_plan_report
from repro.data.synth import dblp_catalog, tpch_catalog, univ_catalog

Q_DBLP = """
Nodes(ID, Name) :- Author(ID, Name).
Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
"""
Q_TPCH = """
Nodes(ID, Name) :- Customer(ID, Name).
Edges(ID1, ID2) :- Orders(ok1, ID1), LineItem(ok1, pk),
                   Orders(ok2, ID2), LineItem(ok2, pk).
"""
Q_UNIV = """
Nodes(ID, Name) :- Instructor(ID, Name).
Nodes(ID, Name) :- Student(ID, Name).
Edges(ID1, ID2) :- TaughtCourse(ID1, courseId), TookCourse(ID2, courseId).
"""

# Small versions of the bench fixtures — the bench gate
# (benchmarks/bench_advisor.py) runs the committed sizes.
FIXTURES = [
    ("dblp", lambda: dblp_catalog(150, 300, 3.0, seed=0), Q_DBLP),
    ("tpch", lambda: tpch_catalog(80, 300, 30, 3.0, seed=0), Q_TPCH),
    ("univ", lambda: univ_catalog(15, 120, 25, 3.0, seed=0), Q_UNIV),
]

# The configs the extraction bench commits as BENCH rows: sharded{1,2,7}
# plus spill{2,7} (see benchmarks/bench_extraction.py).
HAND_PICKED = [
    PlanConfig(n_shards=1),
    PlanConfig(n_shards=2),
    PlanConfig(n_shards=7),
    PlanConfig(n_shards=2, spill=True),
    PlanConfig(n_shards=7, spill=True),
]


def _plan_for(report, cfg: PlanConfig):
    """An executable plan for ``cfg`` riding on the report's query."""
    return dataclasses.replace(report.chosen, config=cfg)


def _median_time(fn, repeats: int = 3) -> float:
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


# ---------------------------------------------------------------------------
# 1. Reality check: chosen plan vs hand-picked bench configs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,make,q", FIXTURES, ids=[f[0] for f in FIXTURES])
def test_chosen_plan_not_worse_than_hand_picked(name, make, q):
    cat = make()
    report = plan(cat, q)
    times = {
        cfg: _median_time(lambda cfg=cfg: _plan_for(report, cfg).execute(cat))
        for cfg in HAND_PICKED
    }
    best_cfg = min(times, key=times.get)
    chosen_cfg = report.chosen.config
    if chosen_cfg in times:
        # same config == same work: the comparison is deterministic
        chosen_t = times[chosen_cfg]
    else:
        chosen_t = _median_time(lambda: report.chosen.execute(cat))
    # 1.25x slack absorbs wall-clock noise when the chosen config is not
    # literally one of the hand-picked rows; the bench gate holds the
    # strict inequality on the committed artifact.
    assert chosen_t <= times[best_cfg] * 1.25, (
        f"{name}: chosen {chosen_cfg} took {chosen_t*1e6:.0f}us vs "
        f"hand-picked {best_cfg} {times[best_cfg]*1e6:.0f}us"
    )


@pytest.mark.parametrize("name,make,q", FIXTURES, ids=[f[0] for f in FIXTURES])
def test_ranked_plans_byte_identical_and_within_bounds(name, make, q):
    """Every plan the optimizer considers equivalent IS equivalent: same
    graph bytes, and the measured budget peaks stay within the ranked
    entry's predicted bounds (the plan-oracle contract)."""
    cat = make()
    report = plan(cat, q)
    ref = extract(cat, q)
    # a diverse sample: chosen + first spill / scatter / multi-shard /
    # unfused entries in rank order
    sample = {report.chosen.config: report.chosen.cost}
    for want in (
        lambda c: c.spill,
        lambda c: c.pack_method == "scatter",
        lambda c: c.n_shards > 1 and not c.spill,
        lambda c: not c.fuse_correction,
    ):
        for cfg, cost in report.ranked:
            if want(cfg):
                sample.setdefault(cfg, cost)
                break
    assert len(sample) >= 4, "plan space collapsed; sample lost coverage"
    for cfg, cost in sample.items():
        res = _plan_for(report, cfg).execute(cat)
        assert graphs_identical(res.graph, ref.graph), f"{name}: {cfg}"
        assert res.budget.peak_resident_rows <= cost.peak_resident_rows, cfg
        assert res.budget.peak_assembly_bytes <= cost.peak_assembly_bytes, cfg


def test_hash_partition_always_pruned():
    cat = dblp_catalog(100, 200, 3.0, seed=0)
    report = plan(cat, Q_DBLP)
    hashed = [p for p in report.pruned if p.config.partition == "hash"]
    assert hashed, "hash partitioning no longer enumerated"
    assert all("byte-identity" in p.reason for p in hashed)
    assert all(cfg.partition == "rows" for cfg, _ in report.ranked)


def test_unsatisfiable_budget_raises_value_error():
    cat = univ_catalog(15, 120, 25, 3.0, seed=0)
    with pytest.raises(ValueError, match="no feasible extraction plan"):
        plan(cat, Q_UNIV, budget=ExtractionBudget(max_resident_rows=1))


def test_budget_prunes_single_shard_before_execution():
    """A budget below the 1-shard bound but above the 8-shard bound must
    steer the choice to more shards — and the chosen plan still runs."""
    cat = dblp_catalog(150, 300, 3.0, seed=0)
    prof = profile_query(cat, Q_DBLP)
    lo = peak_resident_rows_bound(prof, 8)
    hi = peak_resident_rows_bound(prof, 1)
    assert lo < hi
    report = plan(
        cat, Q_DBLP, budget=ExtractionBudget(max_resident_rows=(lo + hi) // 2)
    )
    assert report.chosen.config.n_shards > 1
    assert any("peak resident rows" in p.reason for p in report.pruned)
    res = report.chosen.execute(cat)
    assert graphs_identical(res.graph, extract(cat, Q_DBLP).graph)


def test_measured_pack_throughput_feeds_cost_model():
    """with_measured_pack overrides the analytic pack rates and the
    ranking reacts: a scripted 100x-slower reduceat makes scatter win."""
    from repro.core.condensed import BipartiteEdges
    from repro.kernels.pack import measure_pack_throughput

    rng = np.random.default_rng(3)
    edges = BipartiteEdges(
        rng.integers(0, 50, 400), rng.integers(0, 60, 400), 50, 60
    )
    script = iter([1e-4, 1e-4, 1e-2, 1e-4])  # reduceat, scatter; then again
    rates_fast = measure_pack_throughput(edges, time_fn=lambda fn: next(script))
    rates_slow = measure_pack_throughput(edges, time_fn=lambda fn: next(script))
    assert rates_fast["reduceat"] == pytest.approx(400 / 1e-4)
    assert rates_slow["reduceat"] == pytest.approx(400 / 1e-2)

    cat = dblp_catalog(100, 200, 3.0, seed=0)
    prof = profile_query(cat, Q_DBLP)
    tp_slow = Throughputs().with_measured_pack(rates_slow)
    red = plan_cost(prof, PlanConfig(pack_method="reduceat"), tp_slow)
    sca = plan_cost(prof, PlanConfig(pack_method="scatter"), tp_slow)
    assert sca.pack_s < red.pack_s
    report = plan(cat, Q_DBLP, throughputs=tp_slow)
    assert report.chosen.config.pack_method == "scatter"


# ---------------------------------------------------------------------------
# 2. Properties: monotonicity, soundness, determinism
# ---------------------------------------------------------------------------

_OFFLINE_SEEDS = [0, 7, 23]


def _random_catalog(seed: int) -> Catalog:
    return dblp_catalog(
        50 + seed % 100, 100 + (seed * 7) % 300, 2.0 + (seed % 5), seed=seed
    )


def _check_bounds_monotone_in_rows(seed: int) -> None:
    prof = profile_query(_random_catalog(seed), Q_DBLP)
    factors = (1.0, 1.5, 2.0, 4.0)
    for n in (1, 2, 4):
        for fn in (
            lambda p: peak_resident_rows_bound(p, n),
            lambda p: peak_transient_bytes_bound(p, n),
            lambda p: assembly_account_bounds(p, n)[0],
            lambda p: assembly_account_bounds(p, n)[1],
        ):
            vals = [fn(prof.scaled(f)) for f in factors]
            assert vals == sorted(vals), (seed, n, vals)


def _check_bounds_monotone_in_shards(seed: int) -> None:
    prof = profile_query(_random_catalog(seed), Q_DBLP)
    for fn in (
        peak_resident_rows_bound,
        peak_transient_bytes_bound,
        lambda p, n: assembly_account_bounds(p, n)[1],
    ):
        vals = [fn(prof, n) for n in (1, 2, 4, 8)]
        assert all(a >= b for a, b in zip(vals, vals[1:])), (seed, vals)


def _check_budget_feasible_plan_executes(seed: int) -> None:
    cat = _random_catalog(seed)
    free = plan(cat, Q_DBLP)
    cfg, cost = free.ranked[seed % min(len(free.ranked), 5)]
    budget = ExtractionBudget(
        max_resident_rows=cost.peak_resident_rows,
        max_assembly_bytes=cost.peak_assembly_bytes,
    )
    try:
        report = plan(cat, Q_DBLP, budget=budget)
    except ValueError:
        return  # nothing predicted to fit: soundness is vacuous
    # predicted-to-fit must run to completion (no ExtractionBudgetError)
    res = report.chosen.execute(cat)
    assert graphs_identical(res.graph, extract(cat, Q_DBLP).graph)


def _check_plan_choice_deterministic(seed: int) -> None:
    a = plan(_random_catalog(seed), Q_DBLP)
    b = plan(_random_catalog(seed), Q_DBLP)
    assert a.chosen.config == b.chosen.config
    assert a.to_json() == b.to_json()


@pytest.mark.tier2
@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_bounds_monotone_in_rows(seed):
    _check_bounds_monotone_in_rows(seed)


@pytest.mark.parametrize("seed", _OFFLINE_SEEDS)
def test_bounds_monotone_in_rows_offline(seed):
    _check_bounds_monotone_in_rows(seed)


@pytest.mark.tier2
@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_bounds_monotone_in_shards(seed):
    _check_bounds_monotone_in_shards(seed)


@pytest.mark.parametrize("seed", _OFFLINE_SEEDS)
def test_bounds_monotone_in_shards_offline(seed):
    _check_bounds_monotone_in_shards(seed)


@pytest.mark.tier2
@given(seed=st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_budget_feasible_plan_executes(seed):
    _check_budget_feasible_plan_executes(seed)


@pytest.mark.parametrize("seed", _OFFLINE_SEEDS)
def test_budget_feasible_plan_executes_offline(seed):
    _check_budget_feasible_plan_executes(seed)


@pytest.mark.tier2
@given(seed=st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_plan_choice_deterministic(seed):
    _check_plan_choice_deterministic(seed)


@pytest.mark.parametrize("seed", _OFFLINE_SEEDS)
def test_plan_choice_deterministic_offline(seed):
    _check_plan_choice_deterministic(seed)


# ---------------------------------------------------------------------------
# 3. Golden plan reports (two fixtures, pinned)
# ---------------------------------------------------------------------------

_GOLDEN_PRUNED_ROW = (
    "| {n}-shard hash no-spill pack=reduceat fused | hash partitioning "
    "breaks the order-preserving shard merge (DESIGN.md §7 byte-identity "
    "invariant); only contiguous row shards reproduce the unsharded "
    "output |"
)

GOLDEN_DBLP_REPORT = """## Extraction plan

rules: AuthorPub -[PubID]- AuthorPub
configurations enumerated: 53 (40 feasible, 3 pruned)

**chosen:** 1-shard rows no-spill pack=reduceat fused

- predicted wall time: 0.461 ms
- predicted peak bytes: 1.7MB (assembly account 578.3KB vs available unbounded)
- predicted peak resident rows: 38025 (budget unbounded)
- expected condensed edges: 1285

### Ranked alternatives

| config | predicted wall | peak bytes | vs chosen |
|---|---|---|---|
| 1-shard rows no-spill pack=reduceat fused | 0.461 ms | 1.7MB | **chosen** |
| 1-shard rows no-spill pack=reduceat unfused | 0.581 ms | 1.7MB | +0.120 ms |
| 1-shard rows no-spill pack=scatter fused | 0.589 ms | 1.7MB | +0.129 ms |
| 2-shard rows no-spill pack=reduceat fused | 0.686 ms | 1.1MB | +0.226 ms |

### Pruned plans

| config | why it lost |
|---|---|
{pruned}""".format(
    pruned="\n".join(_GOLDEN_PRUNED_ROW.format(n=n) for n in (2, 4, 8))
)

GOLDEN_UNIV_REPORT = """## Extraction plan

rules: TaughtCourse -[courseId]- TookCourse
configurations enumerated: 53 (40 feasible, 3 pruned)

**chosen:** 1-shard rows no-spill pack=reduceat fused

- predicted wall time: 0.235 ms
- predicted peak bytes: 13.7KB (assembly account 3.8KB vs available unbounded)
- predicted peak resident rows: 396 (budget unbounded)
- expected condensed edges: 144

### Ranked alternatives

| config | predicted wall | peak bytes | vs chosen |
|---|---|---|---|
| 1-shard rows no-spill pack=reduceat fused | 0.235 ms | 13.7KB | **chosen** |
| 1-shard rows no-spill pack=reduceat unfused | 0.249 ms | 13.7KB | +0.014 ms |
| 1-shard rows no-spill pack=scatter fused | 0.249 ms | 13.7KB | +0.014 ms |
| 1-shard rows no-spill pack=scatter unfused | 0.263 ms | 13.7KB | +0.028 ms |

### Pruned plans

| config | why it lost |
|---|---|
{pruned}""".format(
    pruned="\n".join(_GOLDEN_PRUNED_ROW.format(n=n) for n in (2, 4, 8))
)


def _golden_dblp_report() -> PlanReport:
    return plan(dblp_catalog(100, 200, 3.0, seed=0), Q_DBLP)


def _golden_univ_report() -> PlanReport:
    return plan(univ_catalog(10, 60, 12, 3.0, seed=0), Q_UNIV)


def test_golden_dblp_plan_report():
    assert _golden_dblp_report().render() == GOLDEN_DBLP_REPORT


def test_golden_univ_plan_report():
    assert _golden_univ_report().render() == GOLDEN_UNIV_REPORT


@pytest.mark.parametrize(
    "make", [_golden_dblp_report, _golden_univ_report], ids=["dblp", "univ"]
)
def test_plan_report_json_round_trip(make):
    report = make()
    text = report.to_json()
    again = PlanReport.from_json(text)
    assert again == report
    # canonical encoding: round-tripping the round-trip changes nothing
    assert again.to_json() == text
    assert again.render() == report.render()


@pytest.mark.parametrize(
    "make", [_golden_dblp_report, _golden_univ_report], ids=["dblp", "univ"]
)
def test_plan_report_save_load_round_trip(make, tmp_path):
    report = make()
    path = str(tmp_path / "plan.json")
    save_plan_report(report, path)
    loaded = load_plan_report(path)
    assert loaded == report
    assert loaded.to_json() == report.to_json()


# ---------------------------------------------------------------------------
# 4. Crossover routing: measured kernel timings steer the decisions
# ---------------------------------------------------------------------------


def _flip_graph():
    """Seeded graph inside the flip window: expansion ratio above the
    1.2 expand margin but below 1 + duplication ratio."""
    from conftest import random_membership_graph

    return random_membership_graph(60, 30, 3.0, np.random.default_rng(11))


def _one_cell_table(pallas_us: float, xla_us: float):
    from repro.kernels.autotune import (
        CrossoverEntry,
        CrossoverTable,
        batch_bucket,
        src_bucket,
    )

    key = ("sum", src_bucket(60), batch_bucket(128))
    return CrossoverTable.from_entries(
        {key: CrossoverEntry(pallas_us=pallas_us, xla_us=xla_us)}
    )


def test_measured_slower_pallas_flips_device_recommendation():
    pytest.importorskip("jax")
    g = _flip_graph()
    base = recommend(g)
    assert base.device_representation == "DEDUP-C"
    assert base.host_representation == "BITMAP-2"
    # the fixture sits in the flip window (see device_representation_costs)
    assert 1.2 < base.expansion_ratio < 1.0 + base.duplication_ratio

    fast = recommend(g, crossover=_one_cell_table(1.0, 10.0))
    assert fast.device_representation == "DEDUP-C"
    assert fast.device_costs is not None
    assert fast.device_costs["DEDUP-C"] <= fast.device_costs["EXP"]

    slow = recommend(g, crossover=_one_cell_table(100.0, 10.0))
    assert slow.device_representation == "EXP"
    assert slow.host_representation == "BITMAP-2"  # host column unchanged
    assert "flips to EXP" in slow.reason
    assert slow.device_costs["EXP"] < slow.device_costs["DEDUP-C"]


def test_exp_pick_not_revisited_by_crossover():
    """EXP/C-DUP picks have no kernel leg: the router must leave them."""
    pytest.importorskip("jax")
    from repro.core.dedup import graph_from_membership

    # disjoint pairs: expansion ratio 1.0 -> ladder picks EXP outright
    g = graph_from_membership(8, [{0, 1}, {2, 3}, {4, 5}, {6, 7}])
    rec = recommend(g, crossover=_one_cell_table(100.0, 10.0))
    assert rec.device_representation == "EXP"
    assert rec.device_costs is None


def test_all_xla_crossover_prunes_fused_configs():
    pytest.importorskip("jax")
    cat = univ_catalog(15, 120, 25, 3.0, seed=0)
    table = _one_cell_table(100.0, 10.0)  # pallas loses everywhere
    report = plan(cat, Q_UNIV, crossover=table)
    assert all(not cfg.fuse_correction for cfg, _ in report.ranked)
    assert any("stands down" in p.reason for p in report.pruned)
    # deterministic under a fixed table too
    again = plan(univ_catalog(15, 120, 25, 3.0, seed=0), Q_UNIV, crossover=table)
    assert again.to_json() == report.to_json()
