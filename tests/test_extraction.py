import numpy as np
import pytest

from repro.core import extract, recommend
from repro.data.synth import dblp_catalog, tpch_catalog, univ_catalog

Q1 = """
Nodes(ID, Name) :- Author(ID, Name).
Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
"""

Q2 = """
Nodes(ID, Name) :- Customer(ID, Name).
Edges(ID1, ID2) :- Orders(ok1, ID1), LineItem(ok1, pk),
                   Orders(ok2, ID2), LineItem(ok2, pk).
"""

Q3 = """
Nodes(ID, Name) :- Instructor(ID, Name).
Nodes(ID, Name) :- Student(ID, Name).
Edges(ID1, ID2) :- TaughtCourse(ID1, courseId), TookCourse(ID2, courseId).
"""


@pytest.fixture(scope="module")
def dblp():
    return dblp_catalog(n_authors=400, n_pubs=700, mean_authors_per_pub=6.0, seed=1)


def _assert_modes_agree(catalog, query):
    auto = extract(catalog, query, mode="auto")
    exp = extract(catalog, query, mode="expanded")
    cond = extract(catalog, query, mode="condensed")
    Me = exp.graph.expand().adjacency_multiplicity()
    assert (auto.graph.expand().adjacency_multiplicity() == Me).all()
    assert (cond.graph.expand().adjacency_multiplicity() == Me).all()
    return auto, exp, cond


def test_q1_coauthors(dblp):
    auto, exp, cond = _assert_modes_agree(dblp, Q1)
    assert auto.graph.n_virtual > 0, "dense co-author join should be postponed"
    # the paper's central claim: condensed much smaller than expanded
    assert auto.graph.n_edges_condensed < exp.graph.n_edges_condensed
    assert auto.graph.is_single_layer()


def test_q2_tpch_multilayer():
    cat = tpch_catalog(seed=2)
    auto, exp, cond = _assert_modes_agree(cat, Q2)
    # force-condensed postpones all 3 joins (paper Fig 5a)
    assert cond.graph.chains[0].n_layers == 3
    assert auto.plans[0].describe().count("**") >= 1


def test_q3_heterogeneous_bipartite():
    cat = univ_catalog(seed=3)
    auto, exp, _ = _assert_modes_agree(cat, Q3)
    assert auto.nodes.type_ids.max() == 1  # two node types
    # bipartite: instructors only have out-edges (directed graph)
    M = auto.graph.expand().adjacency_multiplicity()
    students = auto.nodes.type_ids == 1
    assert M[students].sum() == 0  # no out-edges from students


def test_selection_predicate(dblp):
    q = """
    Nodes(ID, Name) :- Author(ID, Name).
    Edges(ID1, ID2) :- AuthorPub(ID1, PubID), Pub(PubID, year),
                       AuthorPub(ID2, PubID), year > 2010.
    """
    auto = extract(dblp, q)
    exp = extract(dblp, q, mode="expanded")
    assert (
        auto.graph.expand().adjacency_multiplicity()
        == exp.graph.expand().adjacency_multiplicity()
    ).all()
    # stricter predicate yields a subgraph
    full = extract(dblp, Q1)
    assert auto.graph.n_edges_expanded() <= full.graph.n_edges_expanded()


def test_node_properties(dblp):
    res = extract(dblp, Q1)
    assert "Name" in res.graph.node_properties
    assert res.graph.node_properties["Name"].shape[0] == res.graph.n_real


def test_preprocess_flag(dblp):
    res = extract(dblp, Q1, preprocess=True)
    base = extract(dblp, Q1, preprocess=False)
    assert (
        res.graph.expand().adjacency_multiplicity()
        == base.graph.expand().adjacency_multiplicity()
    ).all()


def test_multiple_edges_statements(dblp):
    q = """
    Nodes(ID, Name) :- Author(ID, Name).
    Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
    Edges(ID1, ID2) :- AuthorPub(ID1, PubID), Pub(PubID, year),
                       AuthorPub(ID2, PubID), year > 2015.
    """
    res = extract(dblp, q)
    exp = extract(dblp, q, mode="expanded")
    assert (
        res.graph.expand().adjacency_multiplicity()
        == exp.graph.expand().adjacency_multiplicity()
    ).all()


def test_empty_node_space(dblp):
    """A Nodes statement matching zero rows must extract an empty graph,
    not crash in NodeSpace.lookup (clip against n-1 == -1 used to index
    the empty key array)."""
    q = """
    Nodes(ID, Name) :- Author(ID, Name), ID < 0.
    Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
    """
    for mode in ("auto", "condensed", "expanded"):
        res = extract(dblp, q, mode=mode)
        assert res.graph.n_real == 0
        assert res.graph.n_edges_expanded() == 0
        assert res.dropped_endpoints > 0  # every endpoint missed the space
    # direct lookup contract on an empty space
    from repro.core.extract import NodeSpace
    space = NodeSpace(
        keys=np.empty(0, dtype=np.int64),
        type_ids=np.empty(0, dtype=np.int32),
        type_names=[],
    )
    idx, found = space.lookup(np.array([1, 2, 3]))
    assert idx.shape == found.shape == (3,)
    assert not found.any()


def test_advisor(dblp):
    res = extract(dblp, Q1)
    rec = recommend(res.graph, workload="multi_pass")
    assert rec.host_representation in {"BITMAP-2", "EXP"}
    assert rec.device_representation in {"DEDUP-C", "EXP"}
    rec2 = recommend(res.graph, duplicate_sensitive=False)
    assert rec2.host_representation in {"C-DUP", "EXP"}


def test_temporal_graph_juxtaposition(dblp):
    """Paper §1: 'juxtapose and compare graphs constructed over different
    time periods' — the DSL's comparison predicates are the mechanism."""
    def coauthors(lo, hi):
        return extract(dblp, f"""
            Nodes(ID, Name) :- Author(ID, Name).
            Edges(ID1, ID2) :- AuthorPub(ID1, PubID), Pub(PubID, year),
                               AuthorPub(ID2, PubID), year >= {lo}, year < {hi}.
        """)

    early = coauthors(1990, 2007)
    late = coauthors(2007, 2024)
    full = extract(dblp, Q1)
    e_e = early.graph.n_edges_expanded()
    e_l = late.graph.n_edges_expanded()
    e_f = full.graph.n_edges_expanded()
    assert 0 < e_e < e_f and 0 < e_l < e_f
    # epochs partition the multiset of expanded edges
    import numpy as np
    Me = early.graph.expand().adjacency_multiplicity()
    Ml = late.graph.expand().adjacency_multiplicity()
    Mf = full.graph.expand().adjacency_multiplicity()
    assert (Me + Ml == Mf).all()


def test_planner_auto_never_worse_than_both(dblp):
    """auto mode should match the smaller in-memory footprint of the two
    fixed plans (the paper's §3.1 selectivity decision)."""
    auto = extract(dblp, Q1).graph.nbytes()
    cond = extract(dblp, Q1, mode="condensed").graph.nbytes()
    expd = extract(dblp, Q1, mode="expanded").graph.nbytes()
    assert auto <= max(cond, expd)
    assert auto <= expd  # dense co-author catalog: condensed must win


from hypothesis import given, settings, strategies as st


@given(seed=st.integers(0, 100_000))
@settings(max_examples=25, deadline=None)
def test_random_chain_queries_condensed_equals_expanded(seed):
    """Paper §4.2 generality: for ANY acyclic chain query over an arbitrary
    schema, the condensed extraction is equivalent to full expansion —
    here: random chain length, table sizes, and key cardinalities."""
    import numpy as np
    from repro.core.relational import Catalog, Table

    rng = np.random.default_rng(seed)
    n_rel = int(rng.integers(1, 4))          # joins in the chain
    n_nodes = int(rng.integers(4, 40))
    tables = [Table("NodeTab", {"id": np.arange(n_nodes)})]
    atoms = []
    prev_var, prev_card = "ID1", n_nodes
    for i in range(n_rel):
        card = int(rng.integers(2, 12))
        n_rows = int(rng.integers(2, 60))
        left = rng.integers(0, prev_card, n_rows)
        right = rng.integers(0, card, n_rows)
        name = f"R{i}"
        tables.append(Table(name, {"a": left, "b": right}))
        atoms.append((name, prev_var, f"v{i}"))
        prev_var, prev_card = f"v{i}", card
    # close the chain back to node ids
    n_rows = int(rng.integers(2, 60))
    tables.append(Table("RZ", {
        "a": rng.integers(0, prev_card, n_rows),
        "b": rng.integers(0, n_nodes, n_rows),
    }))
    atoms.append(("RZ", prev_var, "ID2"))
    catalog = Catalog(tables)
    body = ", ".join(f"{r}({a}, {b})" for r, a, b in atoms)
    q = f"Nodes(ID) :- NodeTab(ID).\nEdges(ID1, ID2) :- {body}."

    auto = extract(catalog, q, mode="auto")
    cond = extract(catalog, q, mode="condensed")
    expd = extract(catalog, q, mode="expanded")
    Me = expd.graph.expand().adjacency_multiplicity()
    assert (auto.graph.expand().adjacency_multiplicity() == Me).all()
    assert (cond.graph.expand().adjacency_multiplicity() == Me).all()
    # preprocessing never changes semantics either
    pre = extract(catalog, q, mode="condensed", preprocess=True)
    assert (pre.graph.expand().adjacency_multiplicity() == Me).all()
