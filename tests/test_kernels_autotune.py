"""Exact-parity sweep over every autotune candidate configuration.

No kernel configuration may be selectable by the autotuner/crossover
table without a parity test exercising its shape class here: every
``CANDIDATES`` entry runs against the segment-sum oracle in exact f32
(integer-valued operands make every sum exact, so ``np.array_equal`` —
not allclose — across row-window × feature-tile × batch-tile shapes,
ragged last tiles, ``B ∈ {1, 32, 200}``, reverse dispatch, and the
idempotent min/max semiring variants.  Also pins the autotuner's
selection mechanics (viability filtering, deterministic tie-break) with
an injected timer.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from conftest import random_bipartite

from repro.core.semiring import MAX_TIMES, MIN_PLUS, PLUS_TIMES
from repro.kernels.autotune import (
    CANDIDATES,
    DEFAULT_CONFIG,
    KernelConfig,
    autotune_spmm,
    batch_bucket,
    src_bucket,
)
from repro.kernels.ops import PackedLayer, bitmap_spmm
from repro.kernels.pack import TILE, fits_vmem
from repro.kernels.ref import segment_semiring_ref


def _layer(n_src, n_dst, n_edges, seed):
    rng = np.random.default_rng(seed)
    return PackedLayer.from_edges(random_bipartite(n_src, n_dst, n_edges, rng))


def _int_frontier(n, b, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 7, (n, b)).astype(np.float32))


# Shape classes: ragged last tiles on both axes; the tall one spans more
# than one 512-row window, so every candidate exercises a ragged final
# window too.
SHAPES = [
    (300, 200, 1500),   # ragged src/dst tiles
    (513, 130, 2600),   # > one max row window, ragged everywhere
]


def _cfg_id(cfg):
    return f"rw{cfg.row_window}_fb{cfg.feature_block}"


@pytest.mark.parametrize("config", CANDIDATES, ids=_cfg_id)
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: f"{s[0]}x{s[1]}")
@pytest.mark.parametrize("batch", [1, 32, 200])
def test_candidate_parity_sum(config, shape, batch):
    n_src, n_dst, n_edges = shape
    layer = _layer(n_src, n_dst, n_edges, seed=7)
    x = _int_frontier(n_src, batch, seed=batch)
    got = bitmap_spmm(layer, x, backend="pallas", config=config)
    want = segment_semiring_ref(layer.src, layer.dst, x, n_dst)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("config", CANDIDATES, ids=_cfg_id)
@pytest.mark.parametrize(
    "semiring", [MIN_PLUS, MAX_TIMES], ids=lambda s: s.name
)
def test_candidate_parity_idempotent(config, semiring):
    n_src, n_dst, n_edges = SHAPES[0]
    layer = _layer(n_src, n_dst, n_edges, seed=9)
    x = _int_frontier(n_src, 32, seed=5)
    got = bitmap_spmm(
        layer, x, backend="pallas", config=config, semiring=semiring
    )
    want = segment_semiring_ref(
        layer.src, layer.dst, x, n_dst, semiring=semiring
    )
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("config", CANDIDATES, ids=_cfg_id)
def test_candidate_parity_reverse(config):
    n_src, n_dst, n_edges = SHAPES[0]
    layer = _layer(n_src, n_dst, n_edges, seed=3)
    x = _int_frontier(n_dst, 32, seed=1)
    got = bitmap_spmm(layer, x, backend="pallas", config=config, reverse=True)
    want = segment_semiring_ref(layer.dst, layer.src, x, n_src)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_every_candidate_admissible_at_f32():
    # a candidate the footprint formula rejects at the default width
    # could never be selected — it would be dead weight in the sweep
    for cfg in CANDIDATES:
        assert fits_vmem(
            128, cfg.feature_block, 4, row_window=cfg.row_window
        ), cfg


def test_kernel_config_validation():
    with pytest.raises(ValueError):
        KernelConfig(row_window=100)
    with pytest.raises(ValueError):
        KernelConfig(row_window=0)
    with pytest.raises(ValueError):
        KernelConfig(feature_block=0)
    assert KernelConfig() == DEFAULT_CONFIG


def test_buckets_are_log2():
    assert src_bucket(1) == 0
    assert src_bucket(128) == 7
    assert src_bucket(129) == 8
    assert batch_bucket(200) == 8
    assert src_bucket(2**14) == 14


def test_autotune_picks_fastest_viable_deterministically():
    layer = _layer(300, 200, 1500, seed=7)
    # injected timer: favor the widest window; ties impossible
    costs = {cfg: float(i + 1) for i, cfg in enumerate(CANDIDATES)}
    fake_calls = []

    def fake_time(fn):
        fake_calls.append(fn)
        return costs[CANDIDATES[len(fake_calls) - 1]]

    best, timings = autotune_spmm(layer, 32, time_fn=fake_time)
    assert best == CANDIDATES[0]
    assert set(timings) == set(CANDIDATES)
    # reversed cost order flips the winner — selection is measurement-
    # driven, not position-driven
    fake_calls.clear()

    def fake_time_rev(fn):
        fake_calls.append(fn)
        return float(len(CANDIDATES) - len(fake_calls) + 1)

    best_rev, _ = autotune_spmm(layer, 32, time_fn=fake_time_rev)
    assert best_rev == CANDIDATES[-1]


def test_autotune_skips_unviable_candidates():
    layer = _layer(300, 200, 1500, seed=7)
    huge = KernelConfig(row_window=TILE * 1024, feature_block=128)
    best, timings = autotune_spmm(
        layer, 32, candidates=(huge,), time_fn=lambda fn: 1.0
    )
    assert huge not in timings and best == DEFAULT_CONFIG
