"""Continuous-batching multi-tenant serving tier (DESIGN.md §10).

The tier's four contracts, each tested where it can actually break:

* residency — LRU eviction under the byte budget is loss-free: an
  evicted tenant's next query re-uploads from the retained host arrays
  and answers byte-identically; an unsatisfiable budget raises instead
  of thrashing.
* staleness — a request stamped with a superseded ``graph_version``
  bounces at submit, per tenant (the same stamp is fine on a tenant
  still at that version).
* caches — results are keyed on ``(tenant, kind, node, version)`` and a
  ``LiveGraph.apply_delta`` invalidates exactly the bumped tenant's
  entries; executables are keyed on ``(kind, width, shape signature)``
  and shape-sharing tenants reuse one trace.
* handoff — a version bump quiesces new admissions, drains in-flight
  queries against the old graph, then swaps (the regression for the old
  ``update_graph`` fully-drained-queue requirement).
"""
import numpy as np
import pytest

from conftest import random_membership_graph

from repro.core import dedup, engine
from repro.core.delta import LiveGraph
from repro.core.dedup import graph_from_membership
from repro.core.engine import ResidencyBudget, ResidencyError
from repro.data.synth import dblp_catalog
from repro.launch.cells import place_serving_replicas
from repro.serve import (
    GraphQuery,
    GraphQueryServer,
    GraphServingTier,
    ServeRequest,
    ServerStats,
)

Q_DBLP = (
    "Nodes(ID, Name) :- Author(ID, Name).\n"
    "Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID)."
)


def _two_tenant_tier(budget=None, **kw):
    rng = np.random.default_rng(0)
    tier = GraphServingTier(max_batch=8, budget=budget, **kw)
    tier.add_tenant("A", random_membership_graph(30, 10, 4, rng))
    tier.add_tenant("B", random_membership_graph(26, 9, 4, rng))
    return tier


def _reqs(tenant, kind, nodes, qid0=0):
    return [ServeRequest(qid0 + i, tenant, kind, n) for i, n in enumerate(nodes)]


# ---------------------------------------------------------------------------
# Residency: LRU eviction is loss-free
# ---------------------------------------------------------------------------

def test_lru_evict_then_resubmit_byte_identical():
    ref = _two_tenant_tier()
    want_a = ref.serve(_reqs("A", "bfs", range(4)))
    want_b = ref.serve(_reqs("B", "ppr", range(4), qid0=100))
    per_tenant = {n: t.resident_bytes for n, t in ref.tenants.items()}

    # budget fits one tenant at a time: every switch is an eviction
    budget = ResidencyBudget(max_device_bytes=int(max(per_tenant.values()) * 1.2))
    assert budget.max_device_bytes < sum(per_tenant.values())
    tier = _two_tenant_tier(budget=budget, result_cache=False)
    got_a1 = tier.serve(_reqs("A", "bfs", range(4)))
    got_b = tier.serve(_reqs("B", "ppr", range(4), qid0=100))   # evicts A
    got_a2 = tier.serve(_reqs("A", "bfs", range(4), qid0=200))  # evicts B
    assert budget.n_evictions >= 2
    assert tier.tenants["A"].n_uploads == 2   # evicted and re-uploaded
    for q in want_a:
        assert got_a1[q].tobytes() == want_a[q].tobytes()
        assert got_a2[q + 200].tobytes() == want_a[q].tobytes()
    for q in want_b:
        assert got_b[q].tobytes() == want_b[q].tobytes()


def test_unsatisfiable_budget_raises_instead_of_thrashing():
    tier = _two_tenant_tier(budget=ResidencyBudget(max_device_bytes=64))
    with pytest.raises(ResidencyError, match="budget"):
        tier.serve(_reqs("A", "bfs", [0]))


def test_explicit_evict_frees_budget_and_reload_matches():
    tier = _two_tenant_tier()
    first = tier.serve(_reqs("A", "common_neighbors", range(3)))
    resident = tier.budget.resident_bytes
    tier.evict_tenant("A")
    assert tier.budget.resident_bytes < resident
    assert tier.tenants["A"].device is None
    tier.result_cache_enabled = False   # force recompute on reload
    again = tier.serve(_reqs("A", "common_neighbors", range(3), qid0=50))
    for q in first:
        assert first[q].tobytes() == again[q + 50].tobytes()


# ---------------------------------------------------------------------------
# Staleness: per-tenant version stamps
# ---------------------------------------------------------------------------

def test_stale_version_rejects_across_tenants():
    rng = np.random.default_rng(1)
    tier = _two_tenant_tier()
    fresh = random_membership_graph(30, 10, 4, rng)
    tier.update_tenant("A", fresh, version=3)
    with pytest.raises(ValueError, match="stale"):
        tier.submit(ServeRequest(1, "A", "bfs", 0, graph_version=0))
    # the same stamp is valid on tenant B, which is still at version 0
    assert tier.submit(ServeRequest(2, "B", "bfs", 0, graph_version=0)) is None
    assert tier.submit(ServeRequest(3, "A", "bfs", 0, graph_version=3)) is None
    out = {r.qid for r in tier.drain()}
    assert out == {2, 3}
    with pytest.raises(ValueError, match="increase"):
        tier.update_tenant("A", fresh, version=3)


def test_submit_validation():
    tier = _two_tenant_tier()
    with pytest.raises(ValueError, match="unknown tenant"):
        tier.submit(ServeRequest(1, "nope", "bfs", 0))
    with pytest.raises(ValueError, match="unknown query kind"):
        tier.submit(ServeRequest(1, "A", "pagerank_all", 0))
    with pytest.raises(ValueError, match="out of range"):
        tier.submit(ServeRequest(1, "A", "bfs", 10_000))
    tier.submit(ServeRequest(1, "A", "bfs", 0))
    with pytest.raises(ValueError, match="already pending"):
        tier.submit(ServeRequest(1, "A", "ppr", 1))


# ---------------------------------------------------------------------------
# Result cache: keyed on version, invalidated per tenant
# ---------------------------------------------------------------------------

def _live_tier():
    tier = GraphServingTier(max_batch=8)
    for name, seed in (("A", 0), ("B", 1)):
        cat = dblp_catalog(
            n_authors=40, n_pubs=80, mean_authors_per_pub=3.0, seed=seed
        )
        tier.add_tenant(name, LiveGraph(cat, Q_DBLP, mode="condensed"))
    return tier


def test_result_cache_hit_after_unrelated_tenant_delta():
    tier = _live_tier()
    tier.serve(_reqs("A", "bfs", [0, 1]))
    tier.serve(_reqs("B", "bfs", [0, 1], qid0=10))
    assert tier.result_stats.hits == 0

    # unrelated tenant's write: B bumps, A's cache must survive
    live_b = tier.tenants["B"].live
    live_b.apply_delta(inserts={"AuthorPub": {
        "aid": np.array([0], dtype=np.int64),
        "pid": np.array([999_999], dtype=np.int64),
    }})
    assert tier.tenants["B"].version == int(live_b.version)
    assert tier.result_stats.invalidated > 0

    res = tier.submit(ServeRequest(20, "A", "bfs", 0))
    assert res is not None and res.cached          # A: still a hit
    assert tier.submit(ServeRequest(21, "B", "bfs", 0)) is None   # B: miss
    tier.drain()
    assert tier.result_stats.hits == 1
    # stamps against B's superseded version bounce
    with pytest.raises(ValueError, match="stale"):
        tier.submit(ServeRequest(22, "B", "bfs", 0, graph_version=0))


def test_delta_drains_inflight_against_old_graph():
    tier = _live_tier()
    tier.submit(ServeRequest(1, "B", "bfs", 0))
    old_version = tier.tenants["B"].version
    baseline = GraphServingTier(max_batch=8)
    baseline.add_tenant("B", tier.tenants["B"].host)
    want = baseline.serve(_reqs("B", "bfs", [0], qid0=1))

    tier.tenants["B"].live.apply_delta(inserts={"AuthorPub": {
        "aid": np.array([1], dtype=np.int64),
        "pid": np.array([999_998], dtype=np.int64),
    }})
    handoff = tier.take_handoff()
    assert [r.qid for r in handoff] == [1]
    assert handoff[0].graph_version == old_version
    assert handoff[0].value.tobytes() == want[1].tobytes()
    assert tier.n_pending == 0
    assert not tier.tenants["B"].quiescing


# ---------------------------------------------------------------------------
# Executable cache: shared across shape-sharing graphs, no re-traces
# ---------------------------------------------------------------------------

def test_executable_cache_reuse_across_shape_sharing_graphs():
    # disjoint same-size membership sets over the same node count: the
    # two graphs differ in content but share every array shape, so their
    # shape signatures — and compiled executables — coincide
    ga = graph_from_membership(12, [{0, 1, 2}, {3, 4, 5}, {6, 7, 8}])
    gb = graph_from_membership(12, [{0, 1, 3}, {2, 4, 6}, {5, 7, 8}])
    assert (
        engine.graph_shape_signature(engine.to_device(ga))
        == engine.graph_shape_signature(engine.to_device(gb))
    )
    tier = GraphServingTier(max_batch=4, result_cache=False)
    tier.add_tenant("A", ga, with_counts=False)
    tier.add_tenant("B", gb, with_counts=False)
    out_a = tier.serve(_reqs("A", "bfs", range(4)))
    out_b = tier.serve(_reqs("B", "bfs", range(4), qid0=10))
    assert tier.exec_stats.misses == 1 and tier.exec_stats.hits == 1
    for entry in tier._executables.values():
        assert entry.traces[0] == 1, "shape-sharing tenant re-traced"
    # shared executable, different answers: content still matters
    assert out_a[0].shape == out_b[10].shape
    assert any(out_a[i].tobytes() != out_b[10 + i].tobytes() for i in range(4))


def test_executable_cache_warm_eviction():
    tier = _two_tenant_tier(max_executables=2, result_cache=False)
    tier.serve(_reqs("A", "bfs", range(2)))
    tier.serve(_reqs("A", "ppr", range(2), qid0=10))
    tier.serve(_reqs("A", "common_neighbors", range(2), qid0=20))
    assert tier.exec_stats.evictions == 1
    assert len(tier._executables) == 2


def test_bucket_version_churn_does_not_retrace():
    """Version bumps must not invalidate executables: dispatch strips the
    version (staleness lives in the result cache), so the same (kind,
    width, signature) serves every version with one trace."""
    rng = np.random.default_rng(2)
    g = random_membership_graph(20, 8, 4, rng)
    tier = GraphServingTier(max_batch=4, result_cache=False)
    tier.add_tenant("A", g, with_counts=False)
    tier.serve(_reqs("A", "bfs", range(4)))
    tier.update_tenant("A", g, version=1)
    tier.serve(_reqs("A", "bfs", range(4), qid0=10))
    assert tier.exec_stats.misses == 1
    for entry in tier._executables.values():
        assert entry.traces[0] == 1


# ---------------------------------------------------------------------------
# Quiesce handoff (GraphQueryServer regression + tier)
# ---------------------------------------------------------------------------

def test_server_quiesce_blocks_submits_until_swap_done():
    rng = np.random.default_rng(3)
    g = random_membership_graph(20, 8, 4, rng)
    server = GraphQueryServer(engine.to_device(g))
    server.begin_quiesce()
    with pytest.raises(ValueError, match="quiescing"):
        server.submit(GraphQuery(1, "bfs", 0))
    with pytest.raises(ValueError, match="quiescing"):
        server.run([GraphQuery(2, "bfs", 0)])
    server.end_quiesce()
    server.submit(GraphQuery(3, "bfs", 0))
    assert set(server.flush()) == {3}


def test_tier_quiescing_tenant_rejects_submit():
    tier = _two_tenant_tier()
    tier.tenants["A"].quiescing = True
    with pytest.raises(ValueError, match="quiescing"):
        tier.submit(ServeRequest(1, "A", "bfs", 0))
    # other tenants keep admitting
    assert tier.submit(ServeRequest(2, "B", "bfs", 0)) is None
    tier.tenants["A"].quiescing = False
    tier.drain()


# ---------------------------------------------------------------------------
# ServerStats: occupancy and padding waste
# ---------------------------------------------------------------------------

def test_server_stats_occupancy_math():
    s = ServerStats()
    assert s.occupancy == 1.0 and s.padding_waste == 0.0   # idle: no waste
    s.record_batch(6, 8)
    s.record_batch(8, 8)
    assert s.occupancy == pytest.approx(14 / 16)
    assert s.padding_waste == pytest.approx(2 / 16)
    assert s.batch_widths_used == {8: 2}
    other = ServerStats()
    other.record_batch(2, 4)
    s.merge(other)
    assert s.occupancy == pytest.approx(16 / 20)
    assert s.batch_widths_used == {8: 2, 4: 1}


def test_tier_stats_track_occupancy():
    tier = _two_tenant_tier(result_cache=False)
    tier.serve(_reqs("A", "bfs", range(6)))   # 6 real in an 8-wide bucket
    assert tier.stats.n_batches == 1
    assert tier.stats.occupancy == pytest.approx(6 / 8)
    assert tier.stats.batch_widths_used == {8: 1}


# ---------------------------------------------------------------------------
# Replica placement
# ---------------------------------------------------------------------------

def test_place_serving_replicas_balanced_and_disjoint():
    placements = place_serving_replicas(
        ["A", "B", "C"], n_devices=8, group_size=2, replicas=2
    )
    assert len(placements) == 6
    for p in placements:
        assert len(p.devices) == 2
        assert max(p.devices) < 8
    # a tenant's replicas never share a device group
    for t in "ABC":
        groups = [p.devices for p in placements if p.tenant == t]
        assert len(set(groups)) == len(groups) == 2
    # load balanced to within one replica per group
    load = {}
    for p in placements:
        load[p.devices] = load.get(p.devices, 0) + 1
    assert max(load.values()) - min(load.values()) <= 1


def test_place_serving_replicas_errors():
    with pytest.raises(ValueError, match="group"):
        place_serving_replicas(["A"], n_devices=2, group_size=4)
    with pytest.raises(ValueError, match="distinct"):
        place_serving_replicas(["A"], n_devices=2, group_size=1, replicas=3)


# ---------------------------------------------------------------------------
# End-to-end correctness: the tier is a scheduler, not a new algorithm
# ---------------------------------------------------------------------------

def test_tier_answers_match_direct_algorithms():
    import jax.numpy as jnp

    from repro.core import algorithms

    rng = np.random.default_rng(4)
    g = random_membership_graph(24, 8, 4, rng)
    corr = dedup.build_correction(g)
    dev = engine.to_device(g, correction=corr)
    tier = GraphServingTier(max_batch=4)
    tier.add_tenant("A", g, correction=corr)
    nodes = [0, 3, 7, 11]
    got = tier.serve(_reqs("A", "bfs", nodes))
    want = np.asarray(
        algorithms.bfs_multi(dev, jnp.asarray(nodes, dtype=jnp.int32))
    )
    for i, q in enumerate(nodes):
        assert np.array_equal(got[i], want[:, i]), q


# ---------------------------------------------------------------------------
# Condensation-native analytics kinds (DESIGN.md §11)
# ---------------------------------------------------------------------------

def test_tier_serves_analytics_kinds_against_oracle():
    """scc / triangles / shortest / widest answers equal the dense
    oracle — through the full admission/batching/cache path."""
    import jax.numpy as jnp

    from oracle import (
        bfs_ref,
        dense_adjacency,
        scc_labels_ref,
        triangle_counts_ref,
    )
    from repro.core import algorithms

    rng = np.random.default_rng(6)
    g = random_membership_graph(24, 8, 4, rng)
    A = dense_adjacency(g)
    tier = GraphServingTier(max_batch=4)
    tier.add_tenant("A", g)
    nodes = [0, 3, 7]

    got = tier.serve(_reqs("A", "shortest", nodes))
    d_ref = bfs_ref(A, np.asarray(nodes))
    for i in range(len(nodes)):
        assert np.array_equal(got[i], d_ref[:, i]), i

    got = tier.serve(_reqs("A", "widest", nodes))
    for i in range(len(nodes)):
        assert np.array_equal(got[i] > 0, np.isfinite(d_ref[:, i])), i
        assert np.isposinf(got[i][nodes[i]])

    lab_ref = scc_labels_ref(A)
    got = tier.serve(_reqs("A", "scc", nodes))
    for i, q in enumerate(nodes):
        assert np.array_equal(got[i], (lab_ref == lab_ref[q]).astype(np.float32)), q

    t_ref = triangle_counts_ref(A).astype(np.float32)
    got = tier.serve(_reqs("A", "triangles", nodes))
    for i in range(len(nodes)):
        assert np.array_equal(got[i], t_ref), i

    # host-driven kinds hit the result cache on resubmit
    hits0 = tier.result_stats.hits
    res = tier.submit(ServeRequest(990, "A", "scc", nodes[0]))
    assert res is not None and res.cached
    assert tier.result_stats.hits == hits0 + 1


def test_tier_weighted_kinds_use_tenant_weights_not_shared_closure():
    """Two shape-identical tenants with different layer weights must get
    different `shortest` answers from the SAME cached executable — the
    regression for weights leaking into the shared closure."""
    import jax.numpy as jnp

    from repro.core import algorithms

    rng = np.random.default_rng(2)
    g = random_membership_graph(20, 7, 4, rng)
    sizes = [tuple(ch.layer_sizes) for ch in g.chains]
    w_a = tuple(
        tuple(np.full(s, 1.0, np.float32) for s in ls) for ls in sizes
    )
    w_b = tuple(
        tuple(np.full(s, 3.0, np.float32) for s in ls) for ls in sizes
    )
    tier = GraphServingTier(max_batch=4)
    tier.add_tenant("A", g, layer_weights=w_a)
    tier.add_tenant("B", g, layer_weights=w_b)
    got_a = tier.serve(_reqs("A", "shortest", [0, 5]))
    got_b = tier.serve(_reqs("B", "shortest", [0, 5], qid0=10))
    # one executable serves both (same kind/width/shape signature)
    assert tier.exec_stats.misses == 1
    dev = engine.to_device(g, correction=dedup.build_correction(g))
    for i, (qa, qb) in enumerate(((0, 10), (1, 11))):
        node = [0, 5][i]
        da = np.asarray(algorithms.shortest_paths(dev, node, layer_weights=w_a))
        db = np.asarray(algorithms.shortest_paths(dev, node, layer_weights=w_b))
        assert np.array_equal(got_a[qa], da), node
        assert np.array_equal(got_b[qb], db), node
    # the weights genuinely differ (2-virtual-hop paths cost 2 vs 6)
    finite = np.isfinite(got_a[0]) & (got_a[0] > 0)
    assert (got_b[10][finite] > got_a[0][finite]).all()


def test_tier_rejects_mismatched_weight_structure_at_admission():
    """Weight pytrees that don't match the host chain structure must fail
    at add_tenant (with the tenant's name) — not inside a jitted serve
    step.  Both arity mismatches: wrong chain count (a direct-only graph
    given per-chain weights) and wrong per-chain layer count."""
    rng = np.random.default_rng(3)
    g = random_membership_graph(16, 5, 4, rng)
    n_virt = len(g.chains[0].edges) - 1
    tier = GraphServingTier(max_batch=4)
    with pytest.raises(ValueError, match="tenant 'w'.*chains"):
        tier.add_tenant("w", g, layer_weights=[[1.0] * n_virt] * 3)
    with pytest.raises(ValueError, match="tenant 'c'.*virtual"):
        tier.add_tenant(
            "c", g, layer_capacities=[[1.0] * (n_virt + 1)] * len(g.chains)
        )
    # well-formed weights still admit and serve
    ok = [[1.0] * n_virt for _ in g.chains]
    tier.add_tenant("ok", g, layer_weights=ok, layer_capacities=ok)
    res = tier.serve(_reqs("ok", "shortest", [0]))
    assert np.asarray(res[0]).shape == (16,)
