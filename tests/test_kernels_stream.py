"""Streamed-kernel suite (DESIGN.md §6): the Pallas slot-stream SpMM must
match the segment-reduce oracle across everything the old resident-column
kernel excluded — source columns above the old 8 MiB VMEM budget,
``reverse=True`` (transposed packing), idempotent semirings (min/max
masked-select variant), ragged last tiles, and B=1 vs B>1 frontiers —
and the auto-dispatchers must actually *send* those cases to the kernel
(no silent XLA fallback)."""
import zlib

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import random_bipartite, random_membership_graph
from oracle import bipartite_semiring_ref

from repro.core import dedup, engine
from repro.core.condensed import BipartiteEdges
from repro.core.semiring import (
    MAX_MIN,
    MAX_TIMES,
    MIN_PLUS,
    OR_AND,
    PLUS_TIMES,
    kernelizable,
)
from repro.kernels.ops import PackedLayer, bitmap_spmm, resolve_backend
from repro.kernels.pack import (
    TILE,
    fits_vmem,
    pack_bipartite,
    streamed_footprint_bytes,
)
# The lifted budget: the old dispatcher kept the (n_src_pad, Fb) source
# column resident and fell back to XLA above this many bytes.
OLD_COLUMN_BUDGET = 8 * 2**20

SEMIRINGS = [PLUS_TIMES, MIN_PLUS, MAX_TIMES, OR_AND, MAX_MIN]


def _frontier(rng, n, b, semiring):
    if semiring is MIN_PLUS:
        x = np.where(rng.random((n, b)) < 0.3, rng.random((n, b)), np.inf)
    elif semiring is MAX_MIN:
        # widths: mostly-zero non-negative, a few inf sources
        x = np.where(rng.random((n, b)) < 0.3, rng.random((n, b)), 0.0)
        x = np.where(rng.random((n, b)) < 0.05, np.inf, x)
    elif semiring in (MAX_TIMES, OR_AND):
        x = (rng.random((n, b)) < 0.4).astype(np.float64) * rng.random((n, b))
    else:
        x = rng.standard_normal((n, b))
    return x.astype(np.float32)


# ---------------------------------------------------------------------------
# Parity: kernel == segment oracle, all semirings x directions x shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    # (n_src, n_dst, n_edges, B) — ragged last tiles and B=1 vs B>1
    (4, 4, 6, 1),
    (130, 257, 900, 3),
    (300, 300, 3000, 1),
    (513, 200, 4000, 7),
])
@pytest.mark.parametrize("semiring", SEMIRINGS, ids=lambda s: s.name)
@pytest.mark.parametrize("reverse", [False, True])
def test_kernel_matches_segment_oracle(shape, semiring, reverse):
    n_src, n_dst, n_e, b = shape
    # crc32, not hash(): str hashing is salted per process, and a seed
    # that changes every run makes parity failures unreproducible
    seed = zlib.crc32(f"{shape}{semiring.name}{reverse}".encode())
    rng = np.random.default_rng(seed)
    e = random_bipartite(n_src, n_dst, n_e, rng)
    layer = PackedLayer.from_edges(e)
    n_in = n_dst if reverse else n_src
    n_out = n_src if reverse else n_dst
    x = _frontier(rng, n_in, b, semiring)
    # shared NumPy differential oracle (tests/oracle.py) — no JAX on the
    # reference side, so a bug in the segment path can't cancel out
    want = bipartite_semiring_ref(e, x, semiring, reverse=reverse).astype(
        np.float32
    )
    got = np.asarray(bitmap_spmm(
        layer, jnp.asarray(x), backend="pallas",
        semiring=semiring, reverse=reverse,
    ))
    assert got.shape == (n_out, b)
    atol = 1e-4 if semiring is PLUS_TIMES else 0.0
    assert np.allclose(got, want, atol=atol), (
        np.abs(got - want).max(), semiring.name, reverse
    )


def test_vector_frontier_matches_matrix_column():
    """B=1 via a 1-D frontier squeezes back and equals the (n, 1) call."""
    rng = np.random.default_rng(3)
    e = random_bipartite(90, 70, 500, rng)
    layer = PackedLayer.from_edges(e)
    x = rng.standard_normal(90).astype(np.float32)
    y1 = bitmap_spmm(layer, jnp.asarray(x), backend="pallas")
    y2 = bitmap_spmm(layer, jnp.asarray(x[:, None]), backend="pallas")
    assert y1.shape == (70,)
    assert np.array_equal(np.asarray(y1), np.asarray(y2)[:, 0])


# ---------------------------------------------------------------------------
# The lifted cliff: above-old-budget columns dispatch packed, exactly
# ---------------------------------------------------------------------------

def _tall_clustered_edges(rng, n_src=20480, n_dst=200, tiles_hit=10, per=48):
    srcs, dsts = [], []
    for t in rng.choice(n_src // TILE, size=tiles_hit, replace=False):
        s = rng.choice(TILE, size=per, replace=False) + int(t) * TILE
        d = rng.choice(n_dst, size=per, replace=False)
        srcs.append(s)
        dsts.append(d)
    src, dst = np.concatenate(srcs), np.concatenate(dsts)
    key = dst.astype(np.int64) * n_src + src
    _, idx = np.unique(key, return_index=True)
    return BipartiteEdges(src[idx], dst[idx], n_src, n_dst)


def test_above_old_budget_column_dispatches_to_kernel_exactly():
    rng = np.random.default_rng(0)
    e = _tall_clustered_edges(rng)
    layer = PackedLayer.from_edges(e)
    f = 128
    col_bytes = layer.bsb.n_src_tiles * TILE * f * 4
    assert col_bytes > OLD_COLUMN_BUDGET, "test must cross the old cliff"
    # the new streaming-aware formula dispatches to the kernel...
    assert resolve_backend("auto", f, 128, 4) == "pallas"
    assert fits_vmem(f, 128, 4)
    # ...and the footprint really is column-size independent
    assert streamed_footprint_bytes(f, 128, 4) < OLD_COLUMN_BUDGET
    # integer-valued floats: sums are exact in f32, so exact equality
    x = rng.integers(-4, 5, size=(e.n_src, f)).astype(np.float32)
    got = np.asarray(bitmap_spmm(layer, jnp.asarray(x), backend="auto"))
    want = bipartite_semiring_ref(e, x, PLUS_TIMES).astype(np.float32)
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# Engine dispatch: forward, reverse, and idempotent all hit the kernel
# ---------------------------------------------------------------------------

def _packed_pair(seed=11):
    rng = np.random.default_rng(seed)
    g = random_membership_graph(40, 12, 4, rng)
    corr = dedup.build_correction(g)
    return (
        engine.to_device(g, correction=corr),
        engine.to_device_packed(g, correction=corr, backend="pallas"),
        g,
        rng,
    )


@pytest.mark.parametrize("reverse", [False, True])
@pytest.mark.parametrize(
    "semiring", SEMIRINGS, ids=lambda s: s.name
)
def test_engine_packed_dispatches_and_matches_segment(reverse, semiring):
    coo, packed, g, rng = _packed_pair()
    X = jnp.asarray(_frontier(rng, g.n_real, 4, semiring))
    engine.reset_kernel_dispatch_count()
    y_seg = np.asarray(engine.propagate(coo, X, semiring, reverse=reverse))
    assert engine.KERNEL_DISPATCH_COUNT == 0  # COO graph: segment path only
    y_pk = np.asarray(engine.propagate(packed, X, semiring, reverse=reverse))
    assert engine.KERNEL_DISPATCH_COUNT > 0, (
        f"{semiring.name} reverse={reverse} fell back to the segment path"
    )
    atol = 1e-4 if semiring is PLUS_TIMES else 0.0
    assert np.allclose(y_pk, y_seg, atol=atol), (semiring.name, reverse)


def test_kernel_applicable_policy():
    _, packed, g, rng = _packed_pair()
    layer = packed.chains[0][0]
    X = jnp.zeros((layer.n_src, 3), jnp.float32)
    for reverse in (False, True):
        for sr in SEMIRINGS:
            assert engine._kernel_applicable(packed, layer, X, sr, reverse)
    # 1-D frontiers and non-kernelizable semirings stay on segment path
    assert not engine._kernel_applicable(
        packed, layer, jnp.zeros(layer.n_src), PLUS_TIMES, False
    )
    # explicit xla backend wins
    import dataclasses
    xla = dataclasses.replace(packed, backend="xla")
    assert not engine._kernel_applicable(xla, layer, X, PLUS_TIMES, False)
    # auto only picks pallas on a real TPU (interpret mode is test-only)
    auto = dataclasses.replace(packed, backend="auto")
    import jax
    expected = jax.default_backend() == "tpu"
    assert engine._kernel_applicable(auto, layer, X, PLUS_TIMES, False) == expected


def test_engine_reverse_equals_transposed_forward():
    """reverse=True on the packed rep == forward on the reversed graph
    (the HITS / out-degree direction), per chain layer."""
    coo, packed, g, rng = _packed_pair(seed=5)
    X = jnp.asarray(rng.standard_normal((g.n_real, 3)).astype(np.float32))
    engine.reset_kernel_dispatch_count()
    y_rev = np.asarray(engine.propagate(packed, X, PLUS_TIMES, reverse=True))
    assert engine.KERNEL_DISPATCH_COUNT > 0
    y_coo = np.asarray(engine.propagate(coo, X, PLUS_TIMES, reverse=True))
    assert np.allclose(y_rev, y_coo, atol=1e-4)


# ---------------------------------------------------------------------------
# Packing: run-table integrity, method equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_pack_methods_identical(seed):
    rng = np.random.default_rng(seed)
    e = random_bipartite(
        int(rng.integers(1, 500)), int(rng.integers(1, 500)),
        int(rng.integers(0, 2500)), rng,
    )
    a = pack_bipartite(e, method="scatter")
    b = pack_bipartite(e, method="reduceat")
    for f in ("slot_src", "slot_row", "bitmaps", "row_start", "row_count"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f


def test_pack_run_table_integrity():
    rng = np.random.default_rng(7)
    e = random_bipartite(700, 400, 2000, rng)
    bsb = pack_bipartite(e)
    n_rt = -(-e.n_dst // TILE)
    assert bsb.row_start.shape == (n_rt,) and bsb.row_count.shape == (n_rt,)
    assert (bsb.row_count >= 1).all()  # empty rows carry a pad slot
    assert bsb.row_count.sum() == bsb.n_slots
    assert np.array_equal(
        bsb.row_start, np.r_[0, np.cumsum(bsb.row_count[:-1])]
    )
    # slots sorted by (row, src tile): the kernel's streaming order
    order_key = bsb.slot_row.astype(np.int64) * (bsb.n_src_tiles + 1) + bsb.slot_src
    real = bsb.bitmaps.any(axis=(1, 2))
    assert (np.diff(order_key[real]) > 0).all()
    for i in range(n_rt):
        assert (bsb.slot_row[bsb.row_start[i]:bsb.row_start[i] + bsb.row_count[i]] == i).all()


def test_zero_source_layer_is_kernel_safe():
    """Pad slots index source tile 0, so a zero-source layer must still
    pad x to one inert tile instead of handing the kernel a 0-row operand."""
    e = BipartiteEdges(np.array([], np.int64), np.array([], np.int64), 0, 256)
    layer = PackedLayer.from_edges(e)
    y = bitmap_spmm(layer, jnp.zeros((0, 4), jnp.float32), backend="pallas")
    assert y.shape == (256, 4) and not np.asarray(y).any()
    y = bitmap_spmm(
        layer, jnp.zeros((256, 4), jnp.float32), backend="pallas", reverse=True
    )
    assert y.shape == (0, 4)


def test_pack_unknown_method_rejected():
    e = BipartiteEdges(np.array([0]), np.array([0]), 1, 1)
    with pytest.raises(ValueError):
        pack_bipartite(e, method="magic")


def test_reverse_pack_is_transpose():
    rng = np.random.default_rng(9)
    e = random_bipartite(300, 150, 1200, rng)
    layer = PackedLayer.from_edges(e)
    fwd = layer.bsb.to_dense()[: e.n_dst, : e.n_src]
    rev = layer.bsb_rev.to_dense()[: e.n_src, : e.n_dst]
    assert np.array_equal(fwd.T, rev)


# ---------------------------------------------------------------------------
# Dispatch plumbing
# ---------------------------------------------------------------------------

def test_resolve_backend_policy():
    assert resolve_backend("pallas", 128, 128, 4) == "pallas"
    assert resolve_backend("xla", 128, 128, 4) == "xla"
    assert resolve_backend("auto", 128, 128, 4) == "pallas"
    assert resolve_backend("auto", 128, 128, 4, packable=False) == "xla"
    # unknown (non-kernelizable) semirings conservatively stay on XLA
    import dataclasses
    weird = dataclasses.replace(PLUS_TIMES, name="weird_sum")
    assert not kernelizable(weird)
    assert resolve_backend("auto", 128, 128, 4, semiring=weird) == "xla"
    # an absurd feature block busts the streamed budget -> xla
    assert resolve_backend("auto", 128, 8192 * 16, 4) == "xla"
    # slot tables are scalar-prefetched into SMEM: a block count past the
    # SMEM budget falls back instead of failing inside Mosaic
    assert resolve_backend("auto", 128, 128, 4, n_slots=1_000_000) == "xla"
    assert resolve_backend("auto", 128, 128, 4, n_slots=10_000) == "pallas"
    assert fits_vmem(128, 128, 4, n_slots=10_000)
    assert not fits_vmem(128, 128, 4, n_slots=1_000_000)


def test_default_interpret_env_override(monkeypatch):
    from repro.kernels.bitmap_spmm import default_interpret

    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    import jax
    assert default_interpret() == (jax.default_backend() != "tpu")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert default_interpret() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert default_interpret() is False
