"""Serving/advisor/engine bugfix regressions (one sweep, DESIGN.md §9).

Three previously silent wrong-answer paths, now either fixed or loudly
surfaced:

* ``BatchedServer`` decoded every slot at ``lengths.max()`` — a slot
  admitted with a shorter prompt (or after a longer neighbor finished)
  attended over other requests' KV positions.  Admission now enforces
  the lockstep invariant (``can_admit`` / ragged ``admit`` raises) and
  ``run`` defers ragged requests until the batch drains.
* ``advisor.recommend`` ran two unbudgeted full expansions to size the
  graph — the advisor could blow the memory wall it advises about.  It
  now takes one budgeted ``expansion_stats`` sweep and attaches the
  ``ExpansionAccounting`` evidence to the ``Recommendation``.
* The fused DEDUP-C epilogue stood down silently (min/max semirings,
  ``hop_weight``, 1-D frontiers, operands never built); the reason is
  now machine-readable on ``DevicePacked.fused_standdown`` and every
  propagate-time miss is counted in ``KERNEL_STANDDOWN_COUNT``.

Plus the serving half of the incremental-extraction contract:
``GraphQueryServer`` rejects queries stamped with a stale
``graph_version`` and ``update_graph`` swaps in a fresh graph under a
strictly increasing version.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import random_membership_graph

from repro.configs.base import TransformerConfig
from repro.core import dedup, engine, recommend
from repro.core.engine import KERNEL_STANDDOWN_COUNT, reset_kernel_dispatch_count
from repro.core.semiring import MIN_PLUS, PLUS_TIMES
from repro.serve import BatchedServer, GraphQuery, GraphQueryServer, Request


# ---------------------------------------------------------------------------
# BatchedServer: ragged admission
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm():
    import jax

    from repro.models import transformer

    cfg = TransformerConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab_size=64, microbatches=1, remat_policy="none",
    )
    return transformer.init_params(jax.random.PRNGKey(0), cfg), cfg


def _req(rid, length, max_new=4, seed=0):
    rng = np.random.default_rng(seed + rid)
    return Request(rid=rid, prompt=rng.integers(0, 64, size=length),
                   max_new_tokens=max_new)


def test_ragged_admission_rejected(lm):
    params, cfg = lm
    server = BatchedServer(params, cfg, batch_slots=3, max_len=32)
    assert server.admit(_req(0, 6))
    assert server.can_admit(_req(1, 6))
    assert not server.can_admit(_req(2, 4))
    with pytest.raises(ValueError, match="ragged"):
        server.admit(_req(3, 4))
    # the failed admission took no slot and corrupted no state
    assert sum(s is not None for s in server.slots) == 1
    assert server.admit(_req(4, 6))


def test_step_uses_common_active_length_not_stale_max(lm):
    """The regression for the ``lengths.max()`` bug: serve a long request
    to completion, then a short one.  Previously the freed slot's stale
    length shifted the short request's attention window past its real
    history; now the decode runs at the active batch's common length and
    matches a fresh server bit-for-bit."""
    params, cfg = lm
    server = BatchedServer(params, cfg, batch_slots=2, max_len=32)
    long_out = server.run([_req(0, 12, max_new=4)])
    assert all(s is None for s in server.slots)
    got = server.run([_req(1, 5, max_new=4)])
    fresh = BatchedServer(params, cfg, batch_slots=2, max_len=32)
    want = fresh.run([_req(1, 5, max_new=4)])
    assert got[1] == want[1]
    assert len(long_out[0]) >= 4


def test_run_defers_ragged_requests_and_serves_all(lm):
    """serve_lm-style traffic: ragged prompts through run() — deferral,
    not rejection — and every request's answer equals the single-request
    decode (batching is a pure throughput optimization)."""
    params, cfg = lm
    server = BatchedServer(params, cfg, batch_slots=3, max_len=32)
    reqs = [_req(i, length, max_new=3)
            for i, length in enumerate([6, 6, 4, 6, 9, 4])]
    out = server.run(reqs)
    assert set(out) == set(range(6))
    assert all(len(v) >= 3 for v in out.values())
    for i, length in enumerate([6, 6, 4, 6, 9, 4]):
        fresh = BatchedServer(params, cfg, batch_slots=3, max_len=32)
        assert fresh.run([_req(i, length, max_new=3)])[i] == out[i], i


# ---------------------------------------------------------------------------
# Fused-epilogue stand-downs: surfaced and counted
# ---------------------------------------------------------------------------

def _packed(backend="pallas", fuse_correction=True, correction=True):
    rng = np.random.default_rng(5)
    g = random_membership_graph(20, 8, 4, rng)
    corr = dedup.build_correction(g) if correction else None
    return g, engine.to_device_packed(
        g, correction=corr, backend=backend, fuse_correction=fuse_correction
    )


def test_standdown_reason_on_packed_operands():
    _, dev = _packed()
    assert dev.fused_standdown == ""  # fused operands built
    _, no_corr = _packed(correction=False)
    assert no_corr.fused_standdown == "no_correction"
    _, disabled = _packed(fuse_correction=False)
    assert disabled.fused_standdown == "fuse_correction_disabled"


def test_standdown_reasons_counted_per_cause():
    g, dev = _packed()
    X = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((g.n_real, 3)).astype(np.float32))
    reset_kernel_dispatch_count()
    engine.propagate(dev, X, PLUS_TIMES)                    # fused runs
    assert KERNEL_STANDDOWN_COUNT == {}
    engine.propagate(dev, X[:, 0], PLUS_TIMES)              # 1-D frontier
    engine.propagate(dev, X, PLUS_TIMES, hop_weight=0.5)    # per-hop weight
    inf = jnp.where(X > 0, X, jnp.inf)
    engine.propagate(dev, inf, MIN_PLUS)                    # non-ring semiring
    assert KERNEL_STANDDOWN_COUNT == {
        "frontier_1d": 1,
        "hop_weight": 1,
        "semiring_min_plus": 1,
    }
    _, xla = _packed(backend="xla")
    engine.propagate(xla, X, PLUS_TIMES)
    assert KERNEL_STANDDOWN_COUNT["backend_xla"] == 1
    _, disabled = _packed(fuse_correction=False)
    engine.propagate(disabled, X, PLUS_TIMES)               # never built
    assert KERNEL_STANDDOWN_COUNT["fuse_correction_disabled"] == 1
    reset_kernel_dispatch_count()
    assert KERNEL_STANDDOWN_COUNT == {}


def test_standdown_path_still_correct():
    """Standing down is a dispatch decision, never a semantics change."""
    g, dev = _packed()
    ref = engine.to_device(g, correction=dedup.build_correction(g))
    X = jnp.asarray(np.random.default_rng(1)
                    .standard_normal((g.n_real, 2)).astype(np.float32))
    got = engine.propagate(dev, X, PLUS_TIMES, hop_weight=0.5)
    want = engine.propagate(ref, X, PLUS_TIMES, hop_weight=0.5)
    assert np.allclose(np.asarray(got), np.asarray(want), atol=1e-4)


# ---------------------------------------------------------------------------
# Advisor: one budgeted sweep, accounting attached
# ---------------------------------------------------------------------------

def test_recommend_single_budgeted_sweep_with_accounting():
    rng = np.random.default_rng(7)
    g = random_membership_graph(40, 12, 5, rng)
    budget = 4 * g.n_paths_expanded() + 64
    rec = recommend(g, workload="multi_pass", budget_triples=budget)
    acct = rec.expansion_accounting
    assert acct is not None
    assert acct.budget_triples == budget
    assert acct.n_chunks >= 1
    assert acct.n_triples_out == g.n_edges_expanded()
    assert 0 < acct.peak_resident_triples <= budget
    # the budgeted single-pass stats equal the legacy two-pass ones
    assert rec.expansion_ratio == pytest.approx(
        g.n_edges_expanded() / max(g.n_edges_condensed, 1)
    )
    assert rec.duplication_ratio == pytest.approx(g.duplication_ratio())


def test_recommend_chunked_sweep_matches_unchunked():
    rng = np.random.default_rng(8)
    g = random_membership_graph(30, 10, 4, rng)
    whole = recommend(g, workload="repeated")
    chunked = recommend(g, workload="repeated", chunk_rows=4)
    assert chunked.expansion_accounting.n_chunks > whole.expansion_accounting.n_chunks
    assert chunked.expansion_ratio == pytest.approx(whole.expansion_ratio)
    assert chunked.duplication_ratio == pytest.approx(whole.duplication_ratio)
    assert chunked.host_representation == whole.host_representation
    assert chunked.device_representation == whole.device_representation


# ---------------------------------------------------------------------------
# GraphQueryServer: graph_version staleness contract
# ---------------------------------------------------------------------------

def _server(version=0, **kwargs):
    rng = np.random.default_rng(9)
    g = random_membership_graph(30, 10, 4, rng)
    corr = dedup.build_correction(g)
    dev = engine.to_device(g, correction=corr, graph_version=version)
    return GraphQueryServer(dev, **kwargs), g


def test_stale_version_submits_rejected():
    server, _ = _server(version=2)
    assert server.graph_version == 2  # inherited from the device graph
    server.submit(GraphQuery(1, "bfs", 0))                    # unstamped: ok
    server.submit(GraphQuery(2, "bfs", 1, graph_version=2))   # current: ok
    with pytest.raises(ValueError, match="stale"):
        server.submit(GraphQuery(3, "bfs", 2, graph_version=1))
    with pytest.raises(ValueError, match="stale"):
        server.run([GraphQuery(4, "ppr", 0, graph_version=3)])
    answers = server.flush()
    assert set(answers) == {1, 2}


def test_update_graph_bumps_version_and_invalidates():
    server, g = _server(version=0)
    # pending queries no longer block the swap: update_graph quiesces new
    # admissions, drains in-flight against the old graph, then swaps
    # (regression-tested in detail in test_serving_tier.py)
    server.submit(GraphQuery(1, "bfs", 0))
    old = server.graph
    corr = dedup.build_correction(g)
    fresh = engine.to_device(g, correction=corr, graph_version=7)
    drained = server.update_graph(fresh, graph_version=7)
    assert set(drained) == {1}   # in-flight answered against the old graph
    assert server.graph_version == 7 and server.graph is fresh
    assert not server.pending and not server.quiescing
    # queries stamped against the superseded version now bounce
    with pytest.raises(ValueError, match="stale"):
        server.submit(GraphQuery(5, "bfs", 0, graph_version=0))
    server.submit(GraphQuery(6, "bfs", 0, graph_version=7))
    server.flush()
    with pytest.raises(ValueError, match="increase"):
        server.update_graph(old, graph_version=7)
    # version-less update of a same-version graph still moves forward
    server.update_graph(fresh)
    assert server.graph_version == 8


def test_version_is_jit_static_metadata():
    """The invalidation mechanism: graph_version lives in the device
    pytree's static metadata, so two versions of the same graph hash
    differently under jit — a bump can never serve a stale executable."""
    rng = np.random.default_rng(11)
    g = random_membership_graph(16, 6, 3, rng)
    a = engine.to_device(g, graph_version=0)
    b = engine.to_device(g, graph_version=1)
    import jax

    la = jax.tree_util.tree_structure(a)
    lb = jax.tree_util.tree_structure(b)
    assert la != lb
    assert "graph_version" in repr(la) or la != lb
