"""Cost-based plan vs hand-picked configs (DESIGN.md §12).

For each bench fixture the optimizer's chosen plan is raced against
every hand-picked config the extraction bench commits as BENCH rows
(``sharded{1,2,7}``, ``spill{2,7}``).  Three claims are asserted and
written to ``BENCH_advisor.json`` for the scripts/check.sh gate:

* ``never_worse_time``: the chosen plan's wall time does not lose to
  the best hand-picked config.  When the chosen config IS the
  measured-best hand row — the common case — the comparison reuses
  that row's measurement and the claim is deterministic; otherwise two
  *distinct* configs are compared across runs and anything within a 5%
  band is a measured tie (full-size shard variants routinely overlap
  run to run), so only a loss beyond that band fails;
* ``never_worse_bytes``: the chosen plan's measured peak residency
  (rows AND assembly bytes) does not exceed the best hand-picked
  config's.  Residency is a budget *constraint*, not the objective:
  when the time race against a distinct config is a measured tie, the
  differing residency is recorded in the artifact but does not fail
  the claim — under a caller budget the planner constrains bytes with
  the sound bounds ``bound_ok`` certifies;
* ``bound_ok``: the cost model's predicted peaks genuinely bound the
  measured peaks — the soundness contract the planner's budget
  pruning relies on.
"""
from __future__ import annotations

import dataclasses
import json
import os

from repro.core import extract, graphs_identical, plan
from repro.core.cost import PlanConfig
from repro.data.synth import dblp_catalog, tpch_catalog, univ_catalog

from .bench_extraction import Q_DBLP, Q_TPCH, Q_UNIV
from .common import emit, time_call

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "BENCH_advisor.json")

# The configs bench_extraction commits as rows (see _sharded_rows /
# _spill_rows there): the hand-picked field the optimizer must beat.
HAND_PICKED = [
    ("sharded1", PlanConfig(n_shards=1)),
    ("sharded2", PlanConfig(n_shards=2)),
    ("sharded7", PlanConfig(n_shards=7)),
    ("spill2", PlanConfig(n_shards=2, spill=True)),
    ("spill7", PlanConfig(n_shards=7, spill=True)),
]


def _cases(smoke: bool):
    if smoke:
        return [
            ("dblp", dblp_catalog(300, 600, 4.0, seed=0), Q_DBLP),
            ("tpch", tpch_catalog(200, 800, 60, 3.0, seed=0), Q_TPCH),
            ("univ", univ_catalog(20, 200, 40, 4.0, seed=0), Q_UNIV),
        ]
    return [
        ("dblp", dblp_catalog(4000, 8000, 6.0, seed=0), Q_DBLP),
        ("tpch", tpch_catalog(2000, 8000, 400, 4.0, seed=0), Q_TPCH),
        ("univ", univ_catalog(100, 2000, 200, 5.0, seed=0), Q_UNIV),
    ]


def _measure(report, cfg: PlanConfig, cat, repeats: int):
    """(median wall s, measured peak rows, measured peak assembly bytes,
    byte_identical graph) for one executable config."""
    p = dataclasses.replace(report.chosen, config=cfg)
    t = time_call(lambda: None if p.execute(cat) else None, repeats=repeats)
    res = p.execute(cat)
    return t, res


def run(smoke: bool = False) -> list:
    repeats = 3 if smoke else 5
    rows, fixtures = [], []
    for name, cat, q in _cases(smoke):
        report = plan(cat, q)
        ref = extract(cat, q)
        chosen_cfg = report.chosen.config

        hand = {}
        for hname, cfg in HAND_PICKED:
            t, res = _measure(report, cfg, cat, repeats)
            assert graphs_identical(res.graph, ref.graph), (name, hname)
            hand[hname] = (cfg, t, res.budget)
        best_hand = min(hand, key=lambda k: hand[k][1])
        best_cfg, best_t, best_budget = hand[best_hand]

        match = next(
            (h for h, (cfg, _, _) in hand.items() if cfg == chosen_cfg), None
        )
        if match is not None:
            _, chosen_t, chosen_budget = hand[match]
        else:
            chosen_t, res = _measure(report, chosen_cfg, cat, repeats)
            chosen_budget = res.budget

        cost = report.chosen.cost
        fx = {
            "name": name,
            "chosen": chosen_cfg.to_json_dict(),
            "chosen_is_hand_row": match,
            "predicted_wall_us": cost.wall_s * 1e6,
            "predicted_peak_rows": cost.peak_resident_rows,
            "predicted_assembly_bytes": cost.peak_assembly_bytes,
            "chosen_us": chosen_t * 1e6,
            "chosen_peak_rows": chosen_budget.peak_resident_rows,
            "chosen_assembly_bytes": chosen_budget.peak_assembly_bytes,
            "best_hand": best_hand,
            "best_hand_us": best_t * 1e6,
            "best_hand_peak_rows": best_budget.peak_resident_rows,
            "best_hand_assembly_bytes": best_budget.peak_assembly_bytes,
            # strict when the comparison is the same measurement; 5% tie
            # band when two distinct configs race across runs
            "never_worse_time": chosen_t
            <= best_t * (1.0 if match == best_hand else 1.05),
            "never_worse_bytes": (
                match != best_hand and chosen_t <= best_t * 1.05
            )
            or (
                chosen_budget.peak_resident_rows
                <= best_budget.peak_resident_rows
                and chosen_budget.peak_assembly_bytes
                <= best_budget.peak_assembly_bytes
            ),
            "bound_ok": (
                chosen_budget.peak_resident_rows <= cost.peak_resident_rows
                and chosen_budget.peak_assembly_bytes
                <= cost.peak_assembly_bytes
            ),
        }
        fixtures.append(fx)
        rows.append((
            f"advisor_{name}_chosen",
            chosen_t * 1e6,
            f"config={match or 'custom'};best_hand={best_hand};"
            f"best_hand_us={best_t * 1e6:.1f};"
            f"never_worse_time={int(fx['never_worse_time'])};"
            f"never_worse_bytes={int(fx['never_worse_bytes'])};"
            f"bound_ok={int(fx['bound_ok'])}",
        ))
        rows.append((
            f"advisor_{name}_predicted",
            cost.wall_s * 1e6,
            f"peak_rows={cost.peak_resident_rows};"
            f"assembly_bytes={cost.peak_assembly_bytes};"
            f"measured_peak_rows={chosen_budget.peak_resident_rows};"
            f"measured_assembly_bytes={chosen_budget.peak_assembly_bytes}",
        ))

    doc = {
        "smoke": smoke,
        "fixtures": fixtures,
        "all_never_worse": all(
            f["never_worse_time"] and f["never_worse_bytes"] for f in fixtures
        ),
        "all_bounds_ok": all(f["bound_ok"] for f in fixtures),
    }
    with open(ARTIFACT, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
    emit(rows)
    return rows
