"""Paper Table 3: large datasets — C-DUP / DEDUP-C(BITMAP role) / EXP.

Layered (multi-layer) and single-layer condensed graphs with controlled
join selectivities (App. C.2 generator), scaled to CPU budget.  On the
TPU engine the BITMAP column's role is played by DEDUP-C (DESIGN.md §2);
host BITMAP-2 preprocessing time is reported alongside.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import algorithms, dedup, engine
from repro.data.synth import layered_condensed

from .common import emit, time_call


def run(smoke: bool = False) -> list:
    rows = []
    if smoke:
        datasets = {
            "layered_1": layered_condensed(
                600, [240, 240], [1_200, 800, 1_200], seed=0, symmetric=False,
            ),
            "layered_2": layered_condensed(
                600, [120, 120], [1_200, 800, 1_200], seed=1, symmetric=False,
            ),
            "single_1": layered_condensed(800, [200], [1_600, 1_600], seed=2),
            "single_2": layered_condensed(400, [8], [1_200, 1_200], seed=3),
        }
    else:
        datasets = {
            # layered: same join structure as TPCH (2 virtual layers)
            "layered_1": layered_condensed(
                30_000, [12_000, 12_000], [60_000, 40_000, 60_000], seed=0,
                symmetric=False,
            ),
            "layered_2": layered_condensed(
                30_000, [6_000, 6_000], [60_000, 40_000, 60_000], seed=1,
                symmetric=False,
            ),
            "single_1": layered_condensed(40_000, [10_000], [80_000, 80_000], seed=2),
            "single_2": layered_condensed(20_000, [200], [60_000, 60_000], seed=3),
        }
    for name, g in datasets.items():
        t0 = time.perf_counter()
        exp = g.expand()
        t_exp = time.perf_counter() - t0
        t0 = time.perf_counter()
        corr = dedup.build_correction_streaming(g)
        t_corr = time.perf_counter() - t0
        rows.append((
            f"large_{name}_stream_acct", 0.0,
            f"paths={corr.accounting.n_paths};"
            f"peak={corr.accounting.peak_resident_triples};"
            f"chunks={corr.accounting.n_chunks}",
        ))
        rows.append((f"large_{name}_expand", t_exp * 1e6,
                     f"edges={exp.n_edges};cdup_edges={g.n_edges_condensed}"))
        rows.append((f"large_{name}_correction", t_corr * 1e6,
                     f"nnz={corr.nnz}"))
        reps = {
            "CDUP": engine.to_device(g),
            "DEDUPC": engine.to_device(g, correction=corr),
            "EXP": engine.to_device(exp),
        }
        for rname, rep in reps.items():
            t = time_call(lambda: algorithms.bfs(rep, 0, max_iters=20), repeats=2)
            rows.append((f"large_{name}_bfs_{rname}", t * 1e6, ""))
            if rname != "CDUP":
                t = time_call(lambda: algorithms.pagerank(rep, num_iters=5), repeats=2)
                rows.append((f"large_{name}_pr_{rname}", t * 1e6, "iters=5"))
        if dedup.is_symmetric_single_layer(g):
            t0 = time.perf_counter()
            dedup.bitmap2(g)
            rows.append((f"large_{name}_bitmap2_prep", (time.perf_counter()-t0) * 1e6, ""))
        elif not g.is_single_layer():
            # paper §5.2.2: multi-layer BITMAP = collapse-to-single-layer
            # (space-explosion-guarded) + single-layer BITMAP-2
            from repro.core.condensed import collapse_to_single_layer

            t0 = time.perf_counter()
            try:
                flat = collapse_to_single_layer(g, max_growth=10.0)
                rep = dedup.bitmap2(flat)
                rows.append((
                    f"large_{name}_bitmap2_multilayer",
                    (time.perf_counter() - t0) * 1e6,
                    f"bitmaps={rep.n_bitmaps};collapsed_edges={flat.n_edges_condensed}",
                ))
            except ValueError as e:
                rows.append((
                    f"large_{name}_bitmap2_multilayer", 0.0,
                    f"skipped={str(e)[:50]}",
                ))
    emit(rows)
    return rows
