"""Paper Table 1: condensed (C-DUP) vs full (EXP) extraction.

Reports edges + extraction time for both modes on DBLP / TPCH / UNIV
relational catalogs (synthetic, paper-shaped; sizes scaled for CPU).

Also exercises the sharded out-of-core pipeline (DESIGN.md §7) on the
DBLP catalog: for n_shards ∈ {1, 2, 7} the sharded build is *asserted*
byte-identical to the unsharded one and then re-run under an enforced
``max_resident_rows`` budget — an assertion failure here fails the whole
bench section, which is the scripts/check.sh gate for budget accounting.

The ``extract_dblp_spill{2,7}`` rows gate the out-of-core assembly path
(DESIGN.md §8) the same way: spilled extraction is asserted
byte-identical, its peak resident assembly bytes are asserted *strictly
below* the no-spill accumulation, and the tree-reduce merge wall time is
recorded (``merge_us`` via a catalog-free ``merge_spilled_graph``
re-merge of the finished spill).
"""
from __future__ import annotations

import os
import tempfile

from repro.core import (
    extract,
    extract_sharded,
    graphs_identical,
    merge_spilled_graph,
)
from repro.data.synth import dblp_catalog, tpch_catalog, univ_catalog

from .common import emit, time_call

Q_DBLP = """
Nodes(ID, Name) :- Author(ID, Name).
Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID).
"""
Q_TPCH = """
Nodes(ID, Name) :- Customer(ID, Name).
Edges(ID1, ID2) :- Orders(ok1, ID1), LineItem(ok1, pk),
                   Orders(ok2, ID2), LineItem(ok2, pk).
"""
Q_UNIV = """
Nodes(ID, Name) :- Instructor(ID, Name).
Nodes(ID, Name) :- Student(ID, Name).
Edges(ID1, ID2) :- TaughtCourse(ID1, courseId), TookCourse(ID2, courseId).
"""


def run(smoke: bool = False) -> list:
    if smoke:
        cases = [
            ("dblp", dblp_catalog(300, 600, 4.0, seed=0), Q_DBLP),
            ("tpch", tpch_catalog(200, 800, 60, 3.0, seed=0), Q_TPCH),
            ("univ", univ_catalog(20, 200, 40, 4.0, seed=0), Q_UNIV),
        ]
    else:
        cases = [
            ("dblp", dblp_catalog(4000, 8000, 6.0, seed=0), Q_DBLP),
            ("tpch", tpch_catalog(2000, 8000, 400, 4.0, seed=0), Q_TPCH),
            ("univ", univ_catalog(100, 2000, 200, 5.0, seed=0), Q_UNIV),
        ]
    repeats = 1 if smoke else 3
    rows = []
    for name, cat, q in cases:
        t_c = time_call(lambda: extract(cat, q, mode="auto"), repeats=repeats)
        res_c = extract(cat, q, mode="auto")
        t_e = time_call(lambda: extract(cat, q, mode="expanded"), repeats=repeats)
        res_e = extract(cat, q, mode="expanded")
        rows.append((
            f"extract_{name}_condensed",
            t_c * 1e6,
            f"edges={res_c.graph.n_edges_condensed}",
        ))
        rows.append((
            f"extract_{name}_full",
            t_e * 1e6,
            f"edges={res_e.graph.n_edges_condensed}",
        ))
        rows.append((
            f"extract_{name}_ratio",
            0.0,
            "edge_ratio=%.2f;time_ratio=%.2f" % (
                res_e.graph.n_edges_condensed
                / max(res_c.graph.n_edges_condensed, 1),
                t_e / max(t_c, 1e-9),
            ),
        ))
    rows.extend(_sharded_rows(cases[0], repeats))
    rows.extend(_spill_rows(cases[0], repeats))
    emit(rows)
    return rows


def _sharded_rows(dblp_case, repeats: int) -> list:
    """Sharded-extraction gate (DESIGN.md §7): byte-identity for
    n_shards ∈ {1, 2, 7} plus an *enforced* peak-resident-rows budget.
    Raises (failing the bench section, and therefore scripts/check.sh)
    if the merge step or the budget accounting regresses."""
    name, cat, q = dblp_case
    base = extract(cat, q, mode="auto")
    rows = []
    for n in (1, 2, 7):
        probe = extract_sharded(cat, q, n_shards=n)
        assert graphs_identical(base.graph, probe.graph), (
            f"sharded extraction (n_shards={n}) is not byte-identical "
            "to the unsharded build"
        )
        peak = probe.budget.peak_resident_rows
        # re-run with the observed peak as a hard cap: accounting must
        # stay within it (ExtractionBudgetError would propagate)
        res = extract_sharded(cat, q, n_shards=n, max_resident_rows=peak)
        assert res.budget.peak_resident_rows <= peak
        t_s = time_call(
            lambda n=n: extract_sharded(cat, q, n_shards=n), repeats=repeats
        )
        rows.append((
            f"extract_{name}_sharded{n}",
            t_s * 1e6,
            f"byte_identical=1;peak_resident_rows={peak};"
            f"budget_enforced={peak}",
        ))
    return rows


def _spill_rows(dblp_case, repeats: int) -> list:
    """Out-of-core assembly gate (DESIGN.md §8): for n_shards ∈ {2, 7}
    the spilled build must be byte-identical to the unsharded one AND its
    peak resident assembly bytes must be strictly below the no-spill
    accumulation (the point of spilling).  Also records the tree-reduce
    merge wall time from a ``merge_spilled_graph`` re-merge of the
    finished spill.  Any assertion failure fails the bench section and
    therefore scripts/check.sh."""
    name, cat, q = dblp_case
    base = extract(cat, q, mode="auto")
    rows = []
    for n in (2, 7):
        resident = extract_sharded(cat, q, n_shards=n)
        with tempfile.TemporaryDirectory() as td:
            sp = os.path.join(td, "spill")
            t_total = time_call(
                lambda n=n, sp=sp: extract_sharded(
                    cat, q, n_shards=n, spill_dir=sp
                ),
                repeats=repeats,
            )
            res = extract_sharded(cat, q, n_shards=n, spill_dir=sp)
            assert graphs_identical(base.graph, res.graph), (
                f"spilled extraction (n_shards={n}) is not byte-identical "
                "to the unsharded build"
            )
            spill_peak = res.budget.peak_assembly_bytes
            resident_peak = resident.budget.peak_assembly_bytes
            assert spill_peak < resident_peak, (
                f"spilling did not reduce peak assembly residency "
                f"({spill_peak} >= {resident_peak})"
            )
            assert res.budget.spilled_bytes > 0
            assert res.budget.resident_assembly_bytes == 0
            # reuse_final=False forces a real tree re-merge from the
            # shard records — this times the reduce, not a final read
            t_merge = time_call(
                lambda sp=sp: merge_spilled_graph(sp, reuse_final=False)[0],
                repeats=repeats,
            )
        rows.append((
            f"extract_{name}_spill{n}",
            t_total * 1e6,
            f"byte_identical=1;spill_peak_bytes={spill_peak};"
            f"resident_peak_bytes={resident_peak};"
            f"spilled_bytes={res.budget.spilled_bytes};"
            f"merge_us={t_merge * 1e6:.1f};"
            f"merge_rounds={res.budget.n_merge_rounds}",
        ))
    return rows
