"""Incremental extraction benchmark: delta apply vs full re-extract.

The DESIGN.md §9 claim measured end to end on the DBLP fixture: after a
small batch of row inserts/deletes, ``LiveGraph.apply_delta`` must beat
a from-scratch ``extract`` of the mutated catalog — while producing the
*byte-identical* graph (asserted here, not assumed; a fast wrong answer
fails the run).  Two delta shapes bound the win:

* ``edge_table`` — insert-only writes to ``AuthorPub``: the append-only
  fast path binds and assembles just the insert tail and merges it
  behind the cached entry — O(delta), not O(table).
* ``node_props`` — delete-then-reinsert of an existing Author key (a
  property update): the node space is rebuilt but the key->id mapping
  comes back identical, so every cached rule entry is reused verbatim.

A third, *ungated* shape (``node_table_structural``) inserts new Author
keys: the id mapping shifts, every chain must re-assemble against the
new node space, and the apply is honestly ~1x a full extract — reported
for scale, not gated on.

Both sides run ``mode="condensed"`` — the representation the paper (and
this repo's serving stack) extracts into.

Also times WAL recovery (``LiveGraph.replay`` over a ``DeltaLog``) and
asserts the replayed graph equals the live one.  Writes
``BENCH_delta.json`` (repo root); scripts/check.sh gates on byte
identity and ``delta_us < full_us`` for every scenario.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.core import (
    DeltaLog,
    LiveGraph,
    extract,
    graphs_identical,
    mutate_catalog,
)
from repro.data.synth import dblp_catalog

from .common import emit

Q_DBLP = (
    "Nodes(ID, Name) :- Author(ID, Name).\n"
    "Edges(ID1, ID2) :- AuthorPub(ID1, PubID), AuthorPub(ID2, PubID)."
)


def _deltas(n_authors: int):
    """(name, inserts, deletes, gated) per scenario.  Gated scenarios
    must beat the full re-extract; the structural node write is reported
    but not gated (see module docstring)."""
    return [
        (
            "edge_table",
            {"AuthorPub": {
                "aid": np.arange(16, dtype=np.int64),
                "pid": np.full(16, 1_000_001, dtype=np.int64),
            }},
            None,
            True,
        ),
        (
            "node_props",
            {"Author": {
                "aid": np.array([7], dtype=np.int64),
                "name": np.array(["author_7_renamed"]),
            }},
            {"Author": ("aid", np.array([7], dtype=np.int64))},
            True,
        ),
        (
            "node_table_structural",
            {"Author": {
                "aid": np.array([n_authors, n_authors + 1], dtype=np.int64),
                "name": np.array([f"author_{n_authors}", f"author_{n_authors + 1}"]),
            }},
            None,
            False,
        ),
    ]


def run(smoke: bool = False):
    n_authors, n_pubs = (4_000, 8_000) if smoke else (8_000, 16_000)
    cat = dblp_catalog(
        n_authors=n_authors, n_pubs=n_pubs, mean_authors_per_pub=4.0, seed=0
    )
    rows = []

    # warm the code paths on a toy catalog so the first timed apply is
    # not also the process's first parse/bind/assemble call
    warm = dblp_catalog(n_authors=50, n_pubs=100, mean_authors_per_pub=2.0,
                        seed=1)
    wlive = LiveGraph(warm, Q_DBLP, mode="condensed")
    for _, ins, dels, _ in _deltas(50):
        wlive.apply_delta(inserts=ins, deletes=dels)
    extract(warm, Q_DBLP, mode="condensed")

    with tempfile.TemporaryDirectory() as tmp:
        log = DeltaLog(os.path.join(tmp, "log"))
        t0 = time.perf_counter()
        live = LiveGraph(cat, Q_DBLP, mode="condensed", log=log)
        base_s = time.perf_counter() - t0
        rows.append(
            ("delta_base_build", base_s * 1e6,
             f"authors={n_authors};pubs={n_pubs}")
        )

        mutated = cat
        scenarios = []
        informational = []
        for name, ins, dels, gated in _deltas(n_authors):
            # one-shot wall time: apply_delta advances live state, so the
            # measurement is a single cold call (the deployment shape)
            t0 = time.perf_counter()
            g, version = live.apply_delta(inserts=ins, deletes=dels)
            delta_s = time.perf_counter() - t0
            mutated = mutate_catalog(mutated, inserts=ins, deletes=dels)
            t0 = time.perf_counter()
            ref = extract(mutated, Q_DBLP, mode="condensed")
            full_s = time.perf_counter() - t0
            identical = graphs_identical(g, ref.graph)
            (scenarios if gated else informational).append({
                "name": name,
                "version": int(version),
                "delta_us": delta_s * 1e6,
                "full_extract_us": full_s * 1e6,
                "speedup": full_s / max(delta_s, 1e-12),
                "byte_identical": bool(identical),
            })
            rows.append(
                (f"delta_apply_{name}", delta_s * 1e6,
                 f"full_us={full_s * 1e6:.0f};"
                 f"speedup={full_s / max(delta_s, 1e-12):.2f}x;"
                 f"identical={identical}")
            )
            assert identical, f"delta scenario {name} diverged from extract"

        # crash recovery: base catalog + certified log -> current graph
        reopened = DeltaLog.open(os.path.join(tmp, "log"))
        t0 = time.perf_counter()
        replayed = LiveGraph.replay(cat, Q_DBLP, reopened, mode="condensed")
        replay_s = time.perf_counter() - t0
        replay_identical = graphs_identical(replayed.graph, live.graph)
        rows.append(
            ("delta_log_replay", replay_s * 1e6,
             f"entries={len(reopened)};identical={replay_identical}")
        )
        assert replay_identical, "log replay diverged from the live graph"

    report = {
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "smoke": bool(smoke),
        "n_authors": n_authors,
        "n_pubs": n_pubs,
        "base_build_us": base_s * 1e6,
        "scenarios": scenarios,
        "informational": informational,
        "replay_us": replay_s * 1e6,
        "replay_entries": len(reopened),
        "replay_byte_identical": bool(replay_identical),
        "byte_identical": all(
            s["byte_identical"] for s in scenarios + informational
        ),
    }
    out_path = os.path.join(os.path.dirname(__file__), "..", "BENCH_delta.json")
    with open(out_path, "w") as fh:
        json.dump(report, fh, indent=2)
    rows.append(
        ("bench_delta_json", 0.0,
         f"scenarios={len(scenarios)};byte_identical={report['byte_identical']}")
    )
    emit(rows)
    return rows
