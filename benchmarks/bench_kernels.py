"""Kernel-level benchmark: streamed bit-packed SpMM vs XLA segment path.

Wall times on CPU are *not* the deliverable (interpret mode executes the
kernel body in Python); the numbers that matter are structural AND
honest: every cell is raced through ``measure_crossover`` — the same
pack-time measurement the engine consults — and ``backend_auto`` is the
decision read back from that table.  The gated invariants (scripts/
check.sh) are (a) ``backend_auto`` NEVER picks the measured-slower
backend in any cell, and (b) at least one real cell exists where the
Pallas kernel beats XLA outright.  The block-dense cells supply (b) even
under interpret mode: few slots, many edges, so the kernel does a
handful of 128x128 MXU dots where the segment path gathers every edge.

Writes ``BENCH_kernels.json`` (repo root) with the measured cells
(per-cell autotuned config, measured times for both backends, dispatch
decision + honesty flag), the old-formula dispatch for the lifted 8 MiB
cliff narrative, and the host-pack before/after
(``np.bitwise_or.at`` scatter vs sort+``reduceat`` fold).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax.numpy as jnp

from repro.core.condensed import BipartiteEdges
from repro.kernels.autotune import batch_bucket, measure_crossover, src_bucket
from repro.kernels.ops import PackedLayer, bitmap_spmm, resolve_backend
from repro.kernels.pack import TILE, pack_bipartite

from .common import emit, time_call

# The old dispatch formula kept the whole (n_src_pad, Fb) source column
# resident in VMEM and fell back to XLA above this budget; reproduced
# here (it no longer exists in the code) to report the lifted cliff.
_OLD_VMEM_COLUMN_BUDGET = 8 * 2**20


def _old_fits(n_src_pad: int, f: int, feature_block: int, itemsize: int) -> bool:
    f_pad = -(-f // feature_block) * feature_block
    return n_src_pad * f_pad * itemsize <= _OLD_VMEM_COLUMN_BUDGET


def _clustered_bipartite(
    n_src: int, n_dst: int, n_src_tiles_hit: int, per_tile: int, rng
) -> BipartiteEdges:
    """Edges concentrated in few source tiles: a tall source column (the
    old cliff regime) with a slot count that stays interpret-friendly."""
    srcs, dsts = [], []
    tiles = rng.choice(max(n_src // TILE, 1), size=n_src_tiles_hit, replace=False)
    for t in tiles:
        lo = int(t) * TILE
        hi = min(lo + TILE, n_src)
        s = rng.choice(np.arange(lo, hi), size=min(per_tile, hi - lo), replace=False)
        d = rng.choice(n_dst, size=s.size, replace=False if s.size <= n_dst else True)
        srcs.append(s)
        dsts.append(d)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    key = dst.astype(np.int64) * n_src + src
    _, idx = np.unique(key, return_index=True)
    return BipartiteEdges(src[idx], dst[idx], n_src, n_dst)


def _block_dense_bipartite(n: int) -> BipartiteEdges:
    """Fully dense n x n incidence: n^2 edges in (n/128)^2 slots — the
    regime where bit-packed MXU dots beat the gather+segment path even
    with the kernel interpreted on CPU."""
    src, dst = np.meshgrid(np.arange(n), np.arange(n))
    return BipartiteEdges(src.ravel(), dst.ravel(), n, n)


def _measured_cell(name: str, kind: str, e: BipartiteEdges, f: int, rng) -> dict:
    """Race one cell through the pack-time measurement and read the
    dispatch decision back the way the engine does."""
    itemsize = 4
    layer = PackedLayer.from_edges(e)
    table = measure_crossover(layer, batch_sizes=(f,))
    entry = table.lookup("sum", layer.n_src, f)
    backend_auto = resolve_backend(
        "auto", f, 128, itemsize, table=table, n_src=layer.n_src
    )
    n_src_pad = layer.bsb.n_src_tiles * TILE
    old_fits = _old_fits(n_src_pad, f, 128, itemsize)
    # parity spot-check: the two backends must agree on this cell
    x = jnp.asarray(rng.standard_normal((layer.n_src, f)).astype(np.float32))
    y_p = np.asarray(bitmap_spmm(layer, x, backend="pallas"))
    y_x = np.asarray(bitmap_spmm(layer, x, backend="xla"))
    assert np.allclose(y_p, y_x, atol=1e-3), f"packed != segment path in {name}"
    measured = entry.backend
    return {
        "name": name,
        "kind": kind,
        "n_src": int(layer.n_src),
        "col_mib": n_src_pad * f * itemsize / 2**20,
        "edges": int(e.n_edges),
        "slots": int(layer.bsb.n_slots),
        "src_bucket": src_bucket(layer.n_src),
        "batch_bucket": batch_bucket(f),
        "row_window": int(entry.row_window),
        "feature_block": int(entry.feature_block),
        "t_packed_us": entry.pallas_us,
        "t_xla_us": entry.xla_us,
        "measured_backend": measured,
        "backend_auto": backend_auto,
        "old_formula_backend": "pallas" if old_fits else "xla",
        "dispatch_honest": backend_auto == measured,
        "pallas_wins": measured == "pallas",
    }


def run(smoke: bool = False) -> list:
    rows = []
    rng = np.random.default_rng(0)
    f = 128
    itemsize = 4

    # -- measured crossover cells ----------------------------------------
    # clustered tall columns (the old 8 MiB cliff regime, where the
    # gather path usually wins on CPU) plus block-dense cells (where the
    # kernel wins outright).  Non-smoke adds more sizes on both sides of
    # the crossover.
    if smoke:
        sweep = [(1024, 4, 64), (20480, 12, 64)]          # 0.5 MiB, 10 MiB
        dense = [256]
    else:
        sweep = [
            (8192, 24, 96),    # 4 MiB: below the old cliff
            (16384, 24, 96),   # 8 MiB: at the old cliff
            (20480, 24, 96),   # 10 MiB: above — old formula fell back
            (65536, 24, 96),   # 32 MiB: far above
        ]
        dense = [256, 512]
    cells = []
    for n_src, tiles_hit, per_tile in sweep:
        e = _clustered_bipartite(n_src, 256, tiles_hit, per_tile, rng)
        cells.append(_measured_cell(f"clustered_n{n_src}", "clustered", e, f, rng))
    for n in dense:
        cells.append(
            _measured_cell(f"block_dense_n{n}", "block_dense",
                           _block_dense_bipartite(n), f, rng)
        )
    for c in cells:
        rows.append(
            (
                f"spmm_{c['name']}",
                c["t_packed_us"],
                f"col_mib={c['col_mib']:.1f};auto={c['backend_auto']};"
                f"measured={c['measured_backend']};"
                f"old_auto={c['old_formula_backend']};"
                f"rw={c['row_window']};t_xla_us={c['t_xla_us']:.1f}",
            )
        )
    dispatch_honest = all(c["dispatch_honest"] for c in cells)
    pallas_wins = sum(c["pallas_wins"] for c in cells)
    fallback_rate_new = sum(c["backend_auto"] != "pallas" for c in cells) / len(cells)
    fallback_rate_old = sum(
        c["old_formula_backend"] != "pallas" for c in cells
    ) / len(cells)

    # -- structural accounting (the roofline terms) ----------------------
    sizes = [(256, 4)] if smoke else [(1024, 12), (2048, 14)]
    for n, density_exp in sizes:
        n_e = n * density_exp
        key = rng.choice(n * n, size=n_e, replace=False)
        e = BipartiteEdges(key % n, key // n, n, n)
        layer = PackedLayer.from_edges(e)
        x = jnp.asarray(rng.standard_normal((n, 128)).astype(np.float32))
        t_xla = time_call(lambda: bitmap_spmm(layer, x, backend="xla"))
        rows.append((f"spmm_xla_n{n}", t_xla * 1e6, f"edges={n_e}"))
        bsb = layer.bsb
        f32_blocks = bsb.n_nonzero_blocks * TILE * TILE * 4
        edge_list = n_e * 8
        rows.append((
            f"spmm_pack_n{n}", 0.0,
            f"packed_bytes={bsb.nbytes()};f32_block_bytes={f32_blocks};"
            f"edge_list_bytes={edge_list};blocks={bsb.n_nonzero_blocks};"
            f"max_k={bsb.max_k}",
        ))

    # -- host pack: unbuffered scatter vs sort+reduceat fold --------------
    # (the sort+fold pays off with edge volume; below ~100k edges the two
    # are a wash, so the smoke size sits just past the crossover)
    n_pack = 32768 if smoke else 65536
    n_e = n_pack * 8
    key = rng.choice(n_pack * n_pack, size=n_e, replace=False)
    e = BipartiteEdges(key % n_pack, key // n_pack, n_pack, n_pack)
    t_scatter = time_call(lambda: pack_bipartite(e, method="scatter"))
    t_reduceat = time_call(lambda: pack_bipartite(e, method="reduceat"))
    rows.append(
        (
            "pack_scatter", t_scatter * 1e6,
            f"edges={n_e};method=np.bitwise_or.at",
        )
    )
    rows.append(
        (
            "pack_reduceat", t_reduceat * 1e6,
            f"edges={n_e};speedup={t_scatter / max(t_reduceat, 1e-12):.2f}x",
        )
    )

    report = {
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "smoke": bool(smoke),
        "dispatch_honest": dispatch_honest,
        "pallas_wins": int(pallas_wins),
        "fallback_rate_old_formula": fallback_rate_old,
        "fallback_rate": fallback_rate_new,
        "cells": cells,
        "pack": {
            "edges": int(n_e),
            "t_scatter_us": t_scatter * 1e6,
            "t_reduceat_us": t_reduceat * 1e6,
            "speedup": t_scatter / max(t_reduceat, 1e-12),
        },
    }
    out_path = os.path.join(
        os.path.dirname(__file__), "..", "BENCH_kernels.json"
    )
    with open(out_path, "w") as fh:
        json.dump(report, fh, indent=2)
    rows.append(
        (
            "bench_kernels_json", 0.0,
            f"dispatch_honest={dispatch_honest};pallas_wins={pallas_wins}",
        )
    )
    emit(rows)
    return rows
