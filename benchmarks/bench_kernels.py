"""Kernel-level benchmark: bit-packed block-sparse SpMM vs XLA segment path.

Wall times on CPU are *not* the deliverable (interpret mode executes the
kernel body in Python); the structural numbers are: packed bytes vs f32
blocks vs edge list, and blocks touched — these drive the TPU roofline
(HBM bytes per condensed SpMV).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.condensed import BipartiteEdges
from repro.kernels.ops import PackedLayer, bitmap_spmm
from repro.kernels.pack import TILE

from .common import emit, time_call


def run(smoke: bool = False) -> list:
    rows = []
    rng = np.random.default_rng(0)
    sizes = [(256, 4)] if smoke else [(1024, 12), (2048, 14)]
    for n, density_exp in sizes:
        n_e = n * density_exp
        key = rng.choice(n * n, size=n_e, replace=False)
        e = BipartiteEdges(key % n, key // n, n, n)
        layer = PackedLayer.from_edges(e)
        x = jnp.asarray(rng.standard_normal((n, 128)).astype(np.float32))
        t_xla = time_call(lambda: bitmap_spmm(layer, x, backend="xla"))
        rows.append((f"spmm_xla_n{n}", t_xla * 1e6, f"edges={n_e}"))
        bsb = layer.bsb
        f32_blocks = bsb.n_nonzero_blocks * TILE * TILE * 4
        edge_list = n_e * 8
        rows.append((
            f"spmm_pack_n{n}", 0.0,
            f"packed_bytes={bsb.nbytes()};f32_block_bytes={f32_blocks};"
            f"edge_list_bytes={edge_list};blocks={bsb.n_nonzero_blocks};"
            f"max_k={bsb.max_k}",
        ))
    emit(rows)
    return rows
