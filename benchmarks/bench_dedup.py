"""Paper Fig 12: deduplication algorithm runtimes + ordering sensitivity,
plus the streaming DEDUP-C budget demonstration (DESIGN.md §2): the
correction for a graph whose full expansion exceeds the triple budget is
built with peak residency (iterator accounting) under that budget, and
the triples are asserted identical to the one-shot build.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import dedup

from .common import emit, paper_datasets


def _streaming_budget_rows(smoke: bool) -> list:
    rows = []
    # Heavily overlapping membership sets: raw paths >> unique pairs.
    rng = np.random.default_rng(9)
    n_real, n_virtual, size = (60, 15, 35) if smoke else (400, 50, 160)
    sets = [
        set(rng.choice(n_real, size=size, replace=False).tolist())
        for _ in range(n_virtual)
    ]
    g = dedup.graph_from_membership(n_real, sets)
    n_paths = g.n_paths_expanded()
    n_unique = g.n_edges_expanded()
    budget = 2 * n_unique + n_unique // 2  # fits the correction, not the expansion

    t0 = time.perf_counter()
    full = dedup.build_correction(g)
    t_full = time.perf_counter() - t0

    for label, kw in (
        ("host", {}),
        ("device", {"device_fold": True}),
    ):
        t0 = time.perf_counter()
        corr = dedup.build_correction_streaming(g, budget_triples=budget, **kw)
        dt = time.perf_counter() - t0
        acct = corr.accounting
        # The budget contract this benchmark exists to demonstrate.
        assert n_paths > budget, "expansion must exceed the budget"
        assert acct.peak_resident_triples <= budget, (
            f"peak {acct.peak_resident_triples} > budget {budget}"
        )
        assert all(
            np.array_equal(a, b) for a, b in zip(full, corr)
        ), "streamed correction must match one-shot build"
        rows.append((
            f"dedup_stream_{label}", dt * 1e6,
            f"budget={budget};peak={acct.peak_resident_triples};"
            f"paths={n_paths};unique={n_unique};chunks={acct.n_chunks};"
            f"merges={acct.n_merges};nnz={corr.nnz}",
        ))
    rows.append((
        "dedup_stream_oneshot_ref", t_full * 1e6,
        f"resident={n_paths};nnz={len(full[0])}",
    ))
    return rows


def run(smoke: bool = False) -> list:
    rows = []
    algos = [
        ("bitmap1", lambda g, o: dedup.bitmap1(g)),
        ("bitmap2", lambda g, o: dedup.bitmap2(g)),
        ("naive_virtual", lambda g, o: dedup.dedup1_naive_virtual_first(g, ordering=o)),
        ("naive_real", lambda g, o: dedup.dedup1_naive_real_first(g, ordering=o)),
        ("greedy_real", lambda g, o: dedup.dedup1_greedy_real_first(g, ordering=o)),
        ("greedy_virtual", lambda g, o: dedup.dedup1_greedy_virtual_first(g, ordering=o)),
        ("dedup2", lambda g, o: dedup.dedup2_greedy(g, ordering=o)),
    ]
    data = paper_datasets(scale=0.03 if smoke else 0.12)
    for name, g in data.items():
        for aname, fn in algos:
            t0 = time.perf_counter()
            res = fn(g, "random")
            dt = time.perf_counter() - t0
            if hasattr(res, "n_bitmaps"):
                derived = f"bitmaps={res.n_bitmaps};bytes={res.nbytes()}"
            else:
                edges = getattr(res, "total_edges", None) or getattr(res, "n_edges", 0)
                derived = f"edges={edges}"
            rows.append((f"dedup_{aname}_{name}", dt * 1e6, derived))
    # Fig 12b: ordering sensitivity on one dataset
    g = data["dblp_like"]
    for ordering in ("identity", "random"):
        res = dedup.dedup1_greedy_virtual_first(g, ordering=ordering)
        rows.append((
            f"dedup_order_{ordering}", res.seconds * 1e6,
            f"edges={res.total_edges}",
        ))
    rows.extend(_streaming_budget_rows(smoke))
    emit(rows)
    return rows
