"""Paper Fig 12: deduplication algorithm runtimes + ordering sensitivity."""
from __future__ import annotations

import numpy as np

from repro.core import dedup

from .common import emit, paper_datasets


def run() -> list:
    rows = []
    algos = [
        ("bitmap1", lambda g, o: dedup.bitmap1(g)),
        ("bitmap2", lambda g, o: dedup.bitmap2(g)),
        ("naive_virtual", lambda g, o: dedup.dedup1_naive_virtual_first(g, ordering=o)),
        ("naive_real", lambda g, o: dedup.dedup1_naive_real_first(g, ordering=o)),
        ("greedy_real", lambda g, o: dedup.dedup1_greedy_real_first(g, ordering=o)),
        ("greedy_virtual", lambda g, o: dedup.dedup1_greedy_virtual_first(g, ordering=o)),
        ("dedup2", lambda g, o: dedup.dedup2_greedy(g, ordering=o)),
    ]
    data = paper_datasets(scale=0.12)
    for name, g in data.items():
        for aname, fn in algos:
            import time

            t0 = time.perf_counter()
            res = fn(g, "random")
            dt = time.perf_counter() - t0
            if hasattr(res, "n_bitmaps"):
                derived = f"bitmaps={res.n_bitmaps};bytes={res.nbytes()}"
            else:
                edges = getattr(res, "total_edges", None) or getattr(res, "n_edges", 0)
                derived = f"edges={edges}"
            rows.append((f"dedup_{aname}_{name}", dt * 1e6, derived))
    # Fig 12b: ordering sensitivity on one dataset
    g = data["dblp_like"]
    for ordering in ("identity", "random"):
        res = dedup.dedup1_greedy_virtual_first(g, ordering=ordering)
        rows.append((
            f"dedup_order_{ordering}", res.seconds * 1e6,
            f"edges={res.total_edges}",
        ))
    emit(rows)
    return rows
