"""Shared benchmark utilities: datasets scaled for CPU, timing helpers."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import numpy as np

from repro.core import dedup, engine
from repro.data.synth import barabasi_albert_condensed, layered_condensed


def time_call(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds; blocks on jax outputs."""
    for _ in range(warmup):
        r = fn()
        jax.block_until_ready(r) if r is not None else None
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn()
        if r is not None:
            jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def paper_datasets(scale: float = 1.0) -> Dict[str, object]:
    """Fig-10-style datasets (scaled to CPU-friendly sizes, same regimes):

    dblp_like   : many small virtual nodes (avg size 2)
    imdb_like   : fewer, larger virtual nodes (avg size 10)
    synthetic_1 : many virtual nodes, avg 7
    synthetic_2 : few, huge overlapping cliques (avg 94)
    """
    s = scale
    return {
        "dblp_like": barabasi_albert_condensed(
            int(5234 * s), int(4100 * s), 2.5, 1.0, seed=1
        ),
        "imdb_like": barabasi_albert_condensed(
            int(4396 * s), int(1000 * s), 10.0, 4.0, seed=2
        ),
        "synthetic_1": barabasi_albert_condensed(
            int(2000 * s), int(2000 * s), 7.0, 3.0, seed=3
        ),
        "synthetic_2": barabasi_albert_condensed(
            int(2000 * s), int(60 * s) + 2, 94.0, 20.0, seed=4
        ),
    }


def representations(g) -> Dict[str, object]:
    """All device representations of one condensed graph."""
    corr = dedup.build_correction_streaming(g)
    reps = {
        "EXP": engine.to_device(g.expand()),
        "C-DUP": engine.to_device(g),
        "DEDUP-C": engine.to_device(g, correction=corr),
    }
    if dedup.is_symmetric_single_layer(g):
        d1 = dedup.dedup1_greedy_virtual_first(g)
        reps["DEDUP-1"] = engine.to_device(d1.graph, deduplicated=True)
    return reps


def emit(rows: List[Tuple[str, float, str]]) -> None:
    """CSV rows per the harness contract: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
