"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME] [--smoke]``

``--smoke`` runs every section at toy sizes — seconds, not minutes — so
scripts/check.sh can gate a PR on all bench code paths actually running
(numbers from a smoke run are not comparable to full runs).

Prints ``name,us_per_call,derived`` CSV rows (plus section headers as
comment lines).  Roofline terms come from the dry-run JSON artifacts
(results/dryrun) when present.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time

SECTIONS = [
    ("extraction", "Table 1: condensed vs full extraction"),
    ("compression", "Fig 10: representation sizes"),
    ("algorithms", "Fig 11/13: algorithm performance per representation"),
    ("dedup", "Fig 12: dedup algorithm runtimes"),
    ("large", "Table 3: large datasets"),
    ("distributed", "Table 4: distributed analytics"),
    ("kernels", "kernel structural benchmark"),
    ("delta", "incremental extraction: delta apply vs full re-extract"),
    ("serving", "continuous-batching multi-tenant serving tier"),
    ("advisor", "cost-based extraction plans vs hand-picked configs"),
]


def run_roofline_summary() -> None:
    d = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    if not os.path.isdir(d):
        print("# roofline: no dry-run artifacts (run repro.launch.dryrun --all)")
        return
    print("# roofline summary from results/dryrun")
    for fname in sorted(os.listdir(d)):
        if not fname.endswith(".json"):
            continue
        with open(os.path.join(d, fname)) as f:
            r = json.load(f)
        if not r.get("ok"):
            print(f"roofline_{fname[:-5]},0.0,FAILED={r.get('error','?')[:60]}")
            continue
        dom = r["dominant"]
        print(
            f"roofline_{fname[:-5]},{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e6:.1f},"
            f"dominant={dom};compute_ms={r['compute_s']*1e3:.2f};"
            f"memory_ms={r['memory_s']*1e3:.2f};collective_ms={r['collective_s']*1e3:.2f};"
            f"useful_ratio={r['useful_ratio']:.3f}"
        )


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run one section")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="toy sizes: exercise every bench code path in seconds",
    )
    args = ap.parse_args()

    t0 = time.time()
    for name, title in SECTIONS:
        if args.only and args.only != name:
            continue
        print(f"# === {title} ===")
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        try:
            if "smoke" in inspect.signature(mod.run).parameters:
                mod.run(smoke=args.smoke)
            else:
                mod.run()
        except Exception as e:  # a failing section must not hide the rest
            print(f"bench_{name}_FAILED,0.0,{type(e).__name__}:{e}")
            import traceback

            traceback.print_exc()
            return 1
    if args.only in (None, "roofline"):
        print("# === Roofline (from dry-run artifacts) ===")
        run_roofline_summary()
    print(f"# total bench time: {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
