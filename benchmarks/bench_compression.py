"""Paper Fig 10: in-memory graph sizes per representation x dataset.

Nodes+edges (and bytes) for EXP / C-DUP / DEDUP-1 / DEDUP-2 / BITMAP-1 /
BITMAP-2, plus the DEDUP-C correction (beyond-paper device dedup).
"""
from __future__ import annotations

from repro.core import dedup

from .common import emit, paper_datasets


def run(smoke: bool = False) -> list:
    rows = []
    for name, g in paper_datasets(scale=0.03 if smoke else 0.25).items():
        exp = g.expand()
        rows.append((f"size_{name}_EXP", 0.0,
                     f"edges={exp.n_edges};bytes={exp.nbytes()}"))
        rows.append((f"size_{name}_CDUP", 0.0,
                     f"edges={g.n_edges_condensed};bytes={g.nbytes()};"
                     f"virt={g.n_virtual}"))
        d1 = dedup.dedup1_greedy_virtual_first(g)
        rows.append((f"size_{name}_DEDUP1", d1.seconds * 1e6,
                     f"edges={d1.total_edges};bytes={d1.graph.nbytes()}"))
        d2 = dedup.dedup2_greedy(g)
        rows.append((f"size_{name}_DEDUP2", d2.seconds * 1e6,
                     f"edges={d2.n_edges};bytes={d2.nbytes()}"))
        b1 = dedup.bitmap1(g)
        rows.append((f"size_{name}_BITMAP1", 0.0,
                     f"bitmaps={b1.n_bitmaps};bytes={b1.nbytes()}"))
        b2 = dedup.bitmap2(g)
        rows.append((f"size_{name}_BITMAP2", 0.0,
                     f"bitmaps={b2.n_bitmaps};bytes={b2.nbytes()}"))
        cs, cd, cm = dedup.build_correction(g)
        rows.append((f"size_{name}_DEDUPC", 0.0,
                     f"corr_nnz={len(cs)};bytes={int(cs.nbytes*2 + cm.nbytes)}"))
    emit(rows)
    return rows
