"""Serving-tier benchmark: continuous batching vs synchronous flush.

The DESIGN.md §10 claims, measured end to end with a discrete-event load
generator (virtual Poisson arrivals, real ``perf_counter``-measured batch
service times — the schedule is reproducible, the latencies are honest):

* ``continuous_vs_sync`` — the same offered load (mixed
  bfs/ppr/common-neighbors, zipf-hot nodes, reference QPS calibrated to
  ~60% of the measured batch-service capacity) through (a) the
  continuous-batching :class:`~repro.serve.tier.GraphServingTier` and
  (b) a synchronous flush-the-queue baseline: no admission during a
  round, every query in a round completes at the round barrier (the
  ``GraphQueryServer.flush`` discipline).  Result caches are OFF in both
  so the p99 win is purely structural scheduling, not memoization.
* ``repeated_queries`` — the same zipf-hot load with the result cache
  on: repeated ``(tenant, kind, node, version)`` lookups must hit.
* ``multi_tenant_eviction`` — three bit-packed tenants under a device
  byte budget smaller than their packed sum: serving round-robin forces
  LRU eviction churn, and every answer must match an unbudgeted
  reference tier byte for byte (eviction is loss-free by construction —
  asserted, not assumed).
* ``bucket_churn`` — batch sizes sweeping every bucket width twice:
  executables are built once per ``(kind, width, signature)`` and never
  re-traced on reuse.

Writes ``BENCH_serving.json`` (repo root); scripts/check.sh gates on
continuous-p99 < sync-p99 at equal offered QPS, batch occupancy, the
result-cache hit rate, eviction byte-identity, and an absolute p99
ceiling from the committed ``benchmarks/serving_baseline.json``.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.engine import ResidencyBudget
from repro.data.synth import barabasi_albert_condensed
from repro.serve.tier import KINDS, GraphServingTier, ServeRequest

from .common import emit


def _percentile_ms(results, q):
    lat = np.array([r.latency for r in results])
    return float(np.percentile(lat, q) * 1e3)


def _workload(n_requests, n_nodes, qps, rng, tenants=("g0",), zipf_a=1.5):
    """Poisson arrivals, zipf-hot nodes, uniform kinds/tenants."""
    gaps = rng.exponential(1.0 / qps, size=n_requests)
    arrivals = np.cumsum(gaps)
    nodes = (rng.zipf(zipf_a, size=n_requests) - 1) % n_nodes
    return [
        ServeRequest(
            qid=i,
            tenant=tenants[int(rng.integers(len(tenants)))],
            kind=KINDS[int(rng.integers(len(KINDS)))],
            node=int(nodes[i]),
            arrival_time=float(arrivals[i]),
        )
        for i in range(n_requests)
    ]


def _run_sync(tier, requests):
    """Synchronous flush baseline on the same tier machinery: admit
    everything pending, then run the whole round behind a barrier — no
    admission mid-round, all completions stamped at round end."""
    reqs = sorted(requests, key=lambda r: r.arrival_time)
    results, i = [], 0
    while i < len(reqs) or tier.n_pending:
        while i < len(reqs) and reqs[i].arrival_time <= tier.now + 1e-12:
            res = tier.submit(reqs[i])
            i += 1
            if res is not None:
                results.append(res)
        if tier.n_pending == 0:
            if i < len(reqs):
                tier.now = reqs[i].arrival_time
                continue
            break
        round_results = []
        while tier.n_pending:                 # the flush barrier
            round_results.extend(tier.step())
        for r in round_results:
            r.done_time = tier.now            # everyone waits for the round
        results.extend(round_results)
    return results


def _reset_clock(tier):
    from repro.serve.server import ServerStats

    tier.now = 0.0
    tier.stats = ServerStats()
    tier.invalidate_results()


def _warm_buckets(tier, graph_nodes, tenant="g0"):
    """Compile every (kind, bucket width) executable before measuring, so
    no measured batch pays trace/compile time."""
    qid = 1_000_000
    for kind in KINDS:
        for width in tier.bucket_widths:
            for j in range(width):
                tier.submit(ServeRequest(qid, tenant, kind, j % graph_nodes))
                qid += 1
            tier.step()


def _calibrate_qps(tier, graph_nodes, rng, max_batch):
    """Reference QPS = 60% of the measured full-batch service capacity
    (tier must be warm: compiles would deflate the capacity estimate)."""
    times = []
    for kind in KINDS:
        t0 = tier.now
        tier.serve([
            ServeRequest(qid=10_000 + k * 100 + j, tenant="g0", kind=kind,
                         node=int(rng.integers(graph_nodes)))
            for k in (1,)
            for j in range(max_batch)
        ])
        times.append(tier.now - t0)
    per_batch = float(np.mean(times))
    return 0.6 * max_batch / per_batch


def run(smoke: bool = False):
    n_real, n_virt = (120, 40) if smoke else (400, 120)
    n_requests = 150 if smoke else 600
    max_batch = 16
    rng = np.random.default_rng(0)
    rows = []

    g = barabasi_albert_condensed(n_real, n_virt, 5.0, 2.0, seed=0)

    # finer buckets than the tier default: under partial load small
    # batches pad to 2/4, not 8, keeping occupancy honest
    buckets = (2, 4, 8, 16)

    # -- continuous vs synchronous flush (result caches OFF in both) --------
    cont = GraphServingTier(
        max_batch=max_batch, bucket_widths=buckets, result_cache=False
    )
    cont.add_tenant("g0", g)
    sync = GraphServingTier(
        max_batch=max_batch, bucket_widths=buckets, result_cache=False
    )
    sync.add_tenant("g0", g)

    _warm_buckets(cont, n_real)
    _warm_buckets(sync, n_real)
    qps = _calibrate_qps(cont, n_real, rng, max_batch)
    _calibrate_qps(sync, n_real, rng, max_batch)   # equalize warm state
    load = _workload(n_requests, n_real, qps, np.random.default_rng(1))

    _reset_clock(cont)
    cont_results = cont.run_load(load)
    _reset_clock(sync)
    sync_results = _run_sync(sync, load)
    assert len(cont_results) == len(sync_results) == n_requests

    cont_p50, cont_p99 = _percentile_ms(cont_results, 50), _percentile_ms(cont_results, 99)
    sync_p50, sync_p99 = _percentile_ms(sync_results, 50), _percentile_ms(sync_results, 99)
    occupancy = cont.stats.occupancy
    # ServerStats is the serving tier's efficiency contract: under offered
    # load the batch slots must actually fill (satellite gate, also
    # enforced against BENCH_serving.json in scripts/check.sh)
    assert occupancy >= 0.25, f"batch occupancy collapsed: {occupancy:.2f}"
    rows.append((
        "serving_continuous_p99", cont_p99 * 1e3,
        f"qps={qps:.0f};p50_ms={cont_p50:.2f};occupancy={occupancy:.2f};"
        f"padding_waste={cont.stats.padding_waste:.2f}",
    ))
    rows.append((
        "serving_sync_flush_p99", sync_p99 * 1e3,
        f"qps={qps:.0f};p50_ms={sync_p50:.2f};"
        f"speedup_p99={sync_p99 / max(cont_p99, 1e-9):.2f}x",
    ))

    # -- repeated queries: result cache on ---------------------------------
    hot = GraphServingTier(max_batch=max_batch, bucket_widths=buckets)
    hot.add_tenant("g0", g)
    _warm_buckets(hot, n_real)
    _reset_clock(hot)
    # hits drain the hot head of the distribution, so the miss stream
    # forms smaller batches; offer 40% of the reference rate to keep the
    # scenario about cache behavior, not miss-path saturation
    hot_results = hot.run_load(
        _workload(n_requests, n_real, 0.4 * qps, np.random.default_rng(2))
    )
    hit_rate = hot.result_stats.hit_rate
    n_cached = sum(1 for r in hot_results if r.cached)
    rows.append((
        "serving_result_cache_p99", _percentile_ms(hot_results, 99) * 1e3,
        f"hit_rate={hit_rate:.2f};cached={n_cached}/{len(hot_results)}",
    ))

    # -- multi-tenant eviction under a byte budget --------------------------
    tenant_graphs = {
        f"t{i}": barabasi_albert_condensed(
            n_real, n_virt, 5.0, 2.0, seed=10 + i
        )
        for i in range(3)
    }
    ref = GraphServingTier(max_batch=max_batch, result_cache=False)
    for name, tg in tenant_graphs.items():
        ref.add_tenant(name, tg, packed=True)
        # force the upload so resident_bytes reflects exactly what the
        # budgeted tier will charge (packed + correction + counts operands)
        ref.serve([ServeRequest(900_000 + hash(name) % 1000, name, "bfs", 0)])
    packed_bytes = {
        name: ref.tenants[name].resident_bytes for name in tenant_graphs
    }
    sum_bytes = sum(packed_bytes.values())
    budget_bytes = int(max(packed_bytes.values()) * 1.5)
    assert max(packed_bytes.values()) <= budget_bytes < sum_bytes
    budget = ResidencyBudget(max_device_bytes=budget_bytes)
    tiered = GraphServingTier(
        max_batch=max_batch, budget=budget, result_cache=False
    )
    for name, tg in tenant_graphs.items():
        tiered.add_tenant(name, tg, packed=True)
    mt_rng = np.random.default_rng(3)
    mt_reqs = [
        ServeRequest(
            qid=i, tenant=f"t{i % 3}", kind=KINDS[i % len(KINDS)],
            node=int(mt_rng.integers(n_real)),
        )
        for i in range(60 if smoke else 120)
    ]
    t0 = time.perf_counter()
    got = tiered.serve(mt_reqs)
    mt_s = time.perf_counter() - t0
    want = ref.serve(mt_reqs)
    identical = all(got[q].tobytes() == want[q].tobytes() for q in want)
    assert identical, "eviction/reload changed answer bytes"
    assert budget.n_evictions > 0, "budget never forced an eviction"
    rows.append((
        "serving_multi_tenant_eviction", mt_s * 1e6,
        f"budget={budget_bytes};sum_packed={sum_bytes};"
        f"evictions={budget.n_evictions};identical={identical}",
    ))

    # -- bucket churn: one trace per (kind, width, signature) ---------------
    churn = GraphServingTier(max_batch=max_batch, result_cache=False)
    churn.add_tenant("g0", g)
    qid = 50_000
    for _round in range(2):
        for width in churn.bucket_widths:
            for j in range(width):
                churn.submit(ServeRequest(qid, "g0", "bfs", j % n_real))
                qid += 1
            churn.step()
    retraces = sum(
        e.traces[0] - 1 for e in churn._executables.values()
    )
    assert retraces == 0, f"{retraces} executables re-traced on reuse"
    rows.append((
        "serving_bucket_churn", 0.0,
        f"executables={churn.exec_stats.misses};"
        f"hits={churn.exec_stats.hits};retraces={retraces}",
    ))

    report = {
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "smoke": bool(smoke),
        "n_real": n_real,
        "n_virtual": n_virt,
        "n_requests": n_requests,
        "reference_qps": qps,
        "continuous": {
            "p50_ms": cont_p50,
            "p99_ms": cont_p99,
            "occupancy": occupancy,
            "padding_waste": cont.stats.padding_waste,
            "n_batches": cont.stats.n_batches,
        },
        "sync_flush": {"p50_ms": sync_p50, "p99_ms": sync_p99},
        "repeated_queries": {
            "result_cache_hit_rate": hit_rate,
            "n_cached": n_cached,
            "p99_ms": _percentile_ms(hot_results, 99),
        },
        "multi_tenant": {
            "budget_bytes": budget_bytes,
            "sum_packed_bytes": sum_bytes,
            "n_evictions": budget.n_evictions,
            "byte_identical": bool(identical),
        },
        "bucket_churn": {
            "executables_built": churn.exec_stats.misses,
            "executable_hits": churn.exec_stats.hits,
            "retraces": retraces,
        },
    }
    out_path = os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serving.json"
    )
    with open(out_path, "w") as fh:
        json.dump(report, fh, indent=2)
    rows.append((
        "bench_serving_json", 0.0,
        f"continuous_p99_ms={cont_p99:.2f};sync_p99_ms={sync_p99:.2f}",
    ))
    emit(rows)
    return rows
