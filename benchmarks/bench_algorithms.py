"""Paper Fig 11 + Fig 13: graph algorithms & micro-ops per representation.

Degree / PageRank / BFS / connected components on every device
representation; results are asserted equal across representations before
timing (correctness is the paper's point, speed the trade-off).

Plus the batched-frontier comparison (DESIGN.md §3): B multi-source
analyses as one (n, B) propagation vs a per-source Python loop — the
amortization that makes the condensed representation pay off under
serving traffic.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import algorithms

from .common import emit, paper_datasets, representations, time_call

BATCH = 16


def _batched_vs_looped(name: str, rname: str, rep, n: int) -> list:
    """Rows for B sources answered batched vs serially."""
    rows = []
    sources = np.arange(BATCH, dtype=np.int32) % n
    srcs_j = jnp.asarray(sources)

    t = time_call(lambda: algorithms.bfs_multi(rep, srcs_j, max_iters=30))
    rows.append((f"bfs{BATCH}_batched_{name}_{rname}", t * 1e6, f"B={BATCH}"))
    t = time_call(
        lambda: [
            algorithms.bfs(rep, int(s), max_iters=30) for s in sources
        ]
    )
    rows.append((f"bfs{BATCH}_looped_{name}_{rname}", t * 1e6, f"B={BATCH}"))

    seeds = algorithms.one_hot_frontier(n, srcs_j)
    t = time_call(
        lambda: algorithms.personalized_pagerank(rep, seeds, num_iters=10)
    )
    rows.append((f"ppr{BATCH}_batched_{name}_{rname}", t * 1e6, f"B={BATCH}"))
    cols = [jnp.asarray(np.asarray(seeds)[:, i]) for i in range(BATCH)]
    t = time_call(
        lambda: [
            algorithms.personalized_pagerank(rep, c, num_iters=10)
            for c in cols
        ]
    )
    rows.append((f"ppr{BATCH}_looped_{name}_{rname}", t * 1e6, f"B={BATCH}"))
    return rows


def run(smoke: bool = False) -> list:
    rows = []
    for name, g in paper_datasets(scale=0.04 if smoke else 0.2).items():
        reps = representations(g)
        # correctness gate (duplicate-sensitive algos skip raw C-DUP)
        ref = np.asarray(algorithms.pagerank(reps["EXP"], num_iters=10))
        for rname, rep in reps.items():
            if rname == "C-DUP":
                continue
            got = np.asarray(algorithms.pagerank(rep, num_iters=10))
            assert np.allclose(got, ref, atol=1e-5), (name, rname)
        for rname, rep in reps.items():
            dup_ok = rname != "C-DUP"
            if dup_ok:
                t = time_call(lambda: algorithms.pagerank(rep, num_iters=10))
                rows.append((f"pagerank_{name}_{rname}", t * 1e6, "iters=10"))
                t = time_call(lambda: algorithms.out_degrees(rep))
                rows.append((f"degree_{name}_{rname}", t * 1e6, ""))
            t = time_call(lambda: algorithms.bfs(rep, 0, max_iters=30))
            rows.append((f"bfs_{name}_{rname}", t * 1e6, ""))
            t = time_call(
                lambda: algorithms.connected_components(rep, max_iters=30)
            )
            rows.append((f"concomp_{name}_{rname}", t * 1e6, ""))
        # batched multi-source vs per-source loop (serving amortization)
        n = g.n_real
        rows.extend(_batched_vs_looped(name, "DEDUP-C", reps["DEDUP-C"], n))
    emit(rows)
    return rows
