"""Paper Fig 11 + Fig 13: graph algorithms & micro-ops per representation.

Degree / PageRank / BFS / connected components on every device
representation; results are asserted equal across representations before
timing (correctness is the paper's point, speed the trade-off).

Plus the batched-frontier comparison (DESIGN.md §3): B multi-source
analyses as one (n, B) propagation vs a per-source Python loop — the
amortization that makes the condensed representation pay off under
serving traffic.

Plus the condensation-native analytics rows (DESIGN.md §11): SCC,
triangles, and the min-plus/max-min weighted semirings, each with an
in-bench parity check (condensed-vs-expanded equality AND batched ==
looped single-source oracle) written to ``BENCH_algorithms.json`` —
scripts/check.sh fails when any parity flag is false.
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core import algorithms, dedup

from .common import emit, paper_datasets, representations, time_call

BATCH = 16


def _batched_vs_looped(name: str, rname: str, rep, n: int) -> list:
    """Rows for B sources answered batched vs serially."""
    rows = []
    sources = np.arange(BATCH, dtype=np.int32) % n
    srcs_j = jnp.asarray(sources)

    t = time_call(lambda: algorithms.bfs_multi(rep, srcs_j, max_iters=30))
    rows.append((f"bfs{BATCH}_batched_{name}_{rname}", t * 1e6, f"B={BATCH}"))
    t = time_call(
        lambda: [
            algorithms.bfs(rep, int(s), max_iters=30) for s in sources
        ]
    )
    rows.append((f"bfs{BATCH}_looped_{name}_{rname}", t * 1e6, f"B={BATCH}"))

    seeds = algorithms.one_hot_frontier(n, srcs_j)
    t = time_call(
        lambda: algorithms.personalized_pagerank(rep, seeds, num_iters=10)
    )
    rows.append((f"ppr{BATCH}_batched_{name}_{rname}", t * 1e6, f"B={BATCH}"))
    cols = [jnp.asarray(np.asarray(seeds)[:, i]) for i in range(BATCH)]
    t = time_call(
        lambda: [
            algorithms.personalized_pagerank(rep, c, num_iters=10)
            for c in cols
        ]
    )
    rows.append((f"ppr{BATCH}_looped_{name}_{rname}", t * 1e6, f"B={BATCH}"))
    return rows


def _analytics_rows(name: str, g, reps) -> list:
    """Condensation-native analytics: timed batched vs looped, with the
    parity verdicts the check.sh gate enforces.  Parity means (a) the
    condensed DEDUP-C result equals the same algorithm on the explicit
    expansion (byte-identical), and (b) the batched path equals the
    looped single-source oracle."""
    dev, exp = reps["DEDUP-C"], reps["EXP"]
    n = g.n_real
    sources = np.arange(BATCH, dtype=np.int32) % n
    srcs_j = jnp.asarray(sources)
    out = []

    def record(algo, parity, batched_s, looped_s):
        out.append({
            "name": f"{algo}_{name}",
            "parity": bool(parity),
            "batched_us": batched_s * 1e6,
            "looped_us": looped_s * 1e6,
            "speedup": looped_s / max(batched_s, 1e-12),
        })

    # min-plus shortest paths (hop costs; weighted parity is covered by
    # the tier-2 oracle suite — here the timing story is batching)
    d_b = np.asarray(algorithms.shortest_paths_multi(dev, srcs_j))
    d_l = np.stack(
        [np.asarray(algorithms.shortest_paths(dev, int(s))) for s in sources],
        axis=1,
    )
    d_exp = np.asarray(algorithms.shortest_paths_multi(exp, srcs_j))
    parity = np.array_equal(d_b, d_l) and np.array_equal(d_b, d_exp)
    t_b = time_call(lambda: algorithms.shortest_paths_multi(dev, srcs_j))
    t_l = time_call(
        lambda: [algorithms.shortest_paths(dev, int(s)) for s in sources]
    )
    record("shortest", parity, t_b, t_l)

    # max-min widest paths
    w_b = np.asarray(algorithms.widest_paths_multi(dev, srcs_j))
    w_l = np.stack(
        [np.asarray(algorithms.widest_paths(dev, int(s))) for s in sources],
        axis=1,
    )
    w_exp = np.asarray(algorithms.widest_paths_multi(exp, srcs_j))
    parity = np.array_equal(w_b, w_l) and np.array_equal(w_b, w_exp)
    t_b = time_call(lambda: algorithms.widest_paths_multi(dev, srcs_j))
    t_l = time_call(
        lambda: [algorithms.widest_paths(dev, int(s)) for s in sources]
    )
    record("widest", parity, t_b, t_l)

    # SCC: pivot batches vs the batch=1 looped oracle
    lab_b = algorithms.scc_labels(dev, batch=BATCH)
    lab_l = algorithms.scc_labels(dev, batch=1)
    lab_exp = algorithms.scc_labels(exp, batch=BATCH)
    parity = np.array_equal(lab_b, lab_l) and np.array_equal(lab_b, lab_exp)
    t_b = time_call(lambda: algorithms.scc_labels(dev, batch=BATCH), repeats=1)
    t_l = time_call(lambda: algorithms.scc_labels(dev, batch=1), repeats=1)
    record("scc", parity, t_b, t_l)

    # triangles: blocked identity sweep vs per-node (block=1) oracle;
    # wedge mode (quadratic correction, raw kernel-path hops) is the
    # timed variant, per-step the cross-check
    block = min(128, n)
    t_wedge = algorithms.triangle_counts(dev, block=block, mode="wedge")
    t_step = algorithms.triangle_counts(dev, block=block, mode="per_step")
    t_exp = algorithms.triangle_counts(exp, block=block)
    t_one = algorithms.triangle_counts(dev, block=1, mode="wedge")
    parity = (
        np.array_equal(t_wedge, t_step)
        and np.array_equal(t_wedge, t_exp)
        and np.array_equal(t_wedge, t_one)
    )
    t_b = time_call(
        lambda: algorithms.triangle_counts(dev, block=block, mode="wedge"),
        repeats=1,
    )
    t_l = time_call(
        lambda: algorithms.triangle_counts(dev, block=1, mode="wedge"),
        repeats=1,
    )
    record("triangles", parity, t_b, t_l)
    return out


def run(smoke: bool = False) -> list:
    rows = []
    analytics = []
    for name, g in paper_datasets(scale=0.04 if smoke else 0.2).items():
        reps = representations(g)
        # correctness gate (duplicate-sensitive algos skip raw C-DUP)
        ref = np.asarray(algorithms.pagerank(reps["EXP"], num_iters=10))
        for rname, rep in reps.items():
            if rname == "C-DUP":
                continue
            got = np.asarray(algorithms.pagerank(rep, num_iters=10))
            assert np.allclose(got, ref, atol=1e-5), (name, rname)
        for rname, rep in reps.items():
            dup_ok = rname != "C-DUP"
            if dup_ok:
                t = time_call(lambda: algorithms.pagerank(rep, num_iters=10))
                rows.append((f"pagerank_{name}_{rname}", t * 1e6, "iters=10"))
                t = time_call(lambda: algorithms.out_degrees(rep))
                rows.append((f"degree_{name}_{rname}", t * 1e6, ""))
            t = time_call(lambda: algorithms.bfs(rep, 0, max_iters=30))
            rows.append((f"bfs_{name}_{rname}", t * 1e6, ""))
            t = time_call(
                lambda: algorithms.connected_components(rep, max_iters=30)
            )
            rows.append((f"concomp_{name}_{rname}", t * 1e6, ""))
        # batched multi-source vs per-source loop (serving amortization)
        n = g.n_real
        rows.extend(_batched_vs_looped(name, "DEDUP-C", reps["DEDUP-C"], n))
        # condensation-native analytics parity + timing (gated); the
        # smallest regime is enough for the gate, every regime on full
        if not smoke or name == "dblp_like":
            analytics.extend(_analytics_rows(name, g, reps))
    report = {
        "smoke": bool(smoke),
        "rows": analytics,
        "all_parity": all(r["parity"] for r in analytics),
    }
    out_path = os.path.join(
        os.path.dirname(__file__), "..", "BENCH_algorithms.json"
    )
    with open(out_path, "w") as fh:
        json.dump(report, fh, indent=2)
    for r in analytics:
        rows.append((
            f"{r['name']}_batched", r["batched_us"],
            f"parity={r['parity']};speedup={r['speedup']:.2f}x",
        ))
        rows.append((f"{r['name']}_looped", r["looped_us"], f"B={BATCH}"))
    emit(rows)
    return rows
