"""Paper Fig 11 + Fig 13: graph algorithms & micro-ops per representation.

Degree / PageRank / BFS / connected components on every device
representation; results are asserted equal across representations before
timing (correctness is the paper's point, speed the trade-off).
"""
from __future__ import annotations

import numpy as np

from repro.core import algorithms

from .common import emit, paper_datasets, representations, time_call


def run() -> list:
    rows = []
    for name, g in paper_datasets(scale=0.2).items():
        reps = representations(g)
        # correctness gate (duplicate-sensitive algos skip raw C-DUP)
        ref = np.asarray(algorithms.pagerank(reps["EXP"], num_iters=10))
        for rname, rep in reps.items():
            if rname == "C-DUP":
                continue
            got = np.asarray(algorithms.pagerank(rep, num_iters=10))
            assert np.allclose(got, ref, atol=1e-5), (name, rname)
        for rname, rep in reps.items():
            dup_ok = rname != "C-DUP"
            if dup_ok:
                t = time_call(lambda: algorithms.pagerank(rep, num_iters=10))
                rows.append((f"pagerank_{name}_{rname}", t * 1e6, "iters=10"))
                t = time_call(lambda: algorithms.out_degrees(rep))
                rows.append((f"degree_{name}_{rname}", t * 1e6, ""))
            t = time_call(lambda: algorithms.bfs(rep, 0, max_iters=30))
            rows.append((f"bfs_{name}_{rname}", t * 1e6, ""))
            t = time_call(
                lambda: algorithms.connected_components(rep, max_iters=30)
            )
            rows.append((f"concomp_{name}_{rname}", t * 1e6, ""))
    emit(rows)
    return rows
