"""Paper Table 4 (Giraph port analog): distributed analytics on the mesh.

Shards the condensed engine's edge arrays over the host mesh and runs
Degree / PageRank / ConnectedComponents on EXP vs condensed+correction,
reporting times and per-device bytes.  On this container the host mesh is
1 CPU device; the same code path drives the 512-chip dry-run cell
(graphgen-paper) — see EXPERIMENTS.md §Dry-run.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import algorithms, dedup, engine
from repro.data.synth import barabasi_albert_condensed

from .common import emit, time_call


def run(smoke: bool = False) -> list:
    rows = []
    if smoke:
        datasets = {
            "S1": barabasi_albert_condensed(400, 10, 20.0, 5.0, seed=0),
            "N1": barabasi_albert_condensed(600, 40, 10.0, 4.0, seed=1),
        }
    else:
        datasets = {
            "S1": barabasi_albert_condensed(5_000, 100, 60.0, 10.0, seed=0),
            "N1": barabasi_albert_condensed(8_000, 400, 25.0, 8.0, seed=1),
        }
    n_dev = len(jax.devices())
    for name, g in datasets.items():
        corr = dedup.build_correction_streaming(g)
        reps = {
            "EXP": engine.to_device(g.expand()),
            "DEDUPC": engine.to_device(g, correction=corr),
        }
        for rname, rep in reps.items():
            t = time_call(lambda: algorithms.out_degrees(rep), repeats=2)
            rows.append((f"dist_{name}_degree_{rname}", t * 1e6,
                         f"devices={n_dev}"))
            t = time_call(lambda: algorithms.pagerank(rep, num_iters=10), repeats=2)
            rows.append((f"dist_{name}_pagerank_{rname}", t * 1e6,
                         f"devices={n_dev}"))
        cdup = engine.to_device(g)
        t = time_call(
            lambda: algorithms.connected_components(cdup, max_iters=30), repeats=2
        )
        rows.append((f"dist_{name}_concomp_CDUP", t * 1e6,
                     f"devices={n_dev}"))
    emit(rows)
    return rows
