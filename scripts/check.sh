#!/usr/bin/env bash
# One-command PR gate: tier-1 tests, tier-2 property tests, smoke benches.
#
# `scripts/check.sh --tier2-oracle` runs ONLY the differential-oracle
# section: the fixed-seed hypothesis oracle suite plus the
# BENCH_algorithms.json parity gate (DESIGN.md §11).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

run_tier2_oracle() {
  echo "== tier-2 oracle: differential oracle suite (fixed seed) =="
  # HYPOTHESIS_PROFILE=oracle-ci (registered in tests/conftest.py) makes
  # example generation derandomized — a red run reproduces with the same
  # command.  Offline (no hypothesis) the @given tests skip via the
  # conftest stub and the seeded _offline twins carry the gate.
  HYPOTHESIS_PROFILE=oracle-ci PYTHONHASHSEED=0 python -m pytest -q \
      tests/test_properties.py tests/test_algorithms_golden.py \
      tests/test_advisor_plan.py

  echo "== algorithm parity rows (BENCH_algorithms.json) =="
  # every new-algorithm row must report parity=true: the condensed
  # DEDUP-C result byte-equal to the explicit expansion AND the batched
  # path byte-equal to the looped single-source oracle.  Batched speedup
  # over the looped oracle is reported (smoke timings are not gated).
  if [ ! -f BENCH_algorithms.json ]; then
    python -m benchmarks.run --smoke --only algorithms > /dev/null
  fi
  python - <<'PY'
import json
with open("BENCH_algorithms.json") as fh:
    r = json.load(fh)
assert r["rows"], "no condensation-native analytics rows ran"
bad = [x["name"] for x in r["rows"] if not x["parity"]]
assert not bad, f"oracle parity failed in: {bad}"
assert r["all_parity"], "all_parity flag disagrees with rows"
print(
    "parity true over "
    + ", ".join(f"{x['name']} ({x['speedup']:.1f}x batched)" for x in r["rows"])
)
PY
}

if [[ "${1:-}" == "--tier2-oracle" ]]; then
  run_tier2_oracle
  echo "== tier-2 oracle gates passed =="
  exit 0
fi

echo "== tier-1 (unit + integration) =="
python -m pytest -x -q -m "not tier2"

echo "== tier-2 (property / statistical) =="
python -m pytest -q -m tier2

echo "== docs check (dead symbol references in README/DESIGN) =="
python scripts/check_docs.py

echo "== smoke benches (every section at toy sizes) =="
# the extraction section asserts sharded-extraction byte-identity and
# budget accounting (DESIGN.md §7) — an ExtractionBudget violation or a
# merge-step mismatch fails this step — and gates the out-of-core
# assembly path (DESIGN.md §8) via the extract_dblp_spill{2,7} rows:
# spilled peak resident assembly bytes must be strictly below the
# no-spill accumulation and the tree-reduce re-merge byte-identical
python -m benchmarks.run --smoke

run_tier2_oracle

echo "== kernels perf cells (BENCH_kernels.json) =="
# the full smoke run above already ran the kernels section and wrote the
# artifact; only assert its cells here (no duplicate interpret-mode sweep).
# Two gates: (a) auto-dispatch is HONEST — no cell where backend_auto
# picks the measured-slower backend (the pre-crossover bug shipped a
# 35x Pallas loss as 'auto'); (b) the kernel earns its keep — at least
# one measured cell where Pallas beats XLA outright.
python - <<'PY'
import json
with open("BENCH_kernels.json") as fh:
    r = json.load(fh)
assert "cells" in r and "pack" in r, r.keys()
liars = [
    c["name"] for c in r["cells"]
    if c["backend_auto"] != c["measured_backend"]
]
assert not liars, f"auto picked a measured-slower backend in: {liars}"
assert r["dispatch_honest"], "dispatch_honest flag disagrees with cells"
wins = [c["name"] for c in r["cells"] if c["pallas_wins"]]
assert wins, f"no cell where Pallas beats XLA: {r['cells']}"
print(
    f"dispatch honest over {len(r['cells'])} cells; pallas wins in "
    f"{wins}; fallback_rate={r['fallback_rate']} (old formula: "
    f"{r['fallback_rate_old_formula']}); pack speedup "
    f"{r['pack']['speedup']:.2f}x over {r['pack']['edges']} edges"
)
PY

echo "== incremental extraction (BENCH_delta.json) =="
# the smoke run above already ran the delta section and wrote the
# artifact; assert its claims here.  Three gates: (a) every scenario —
# gated or informational — produced a graph byte-identical to a fresh
# extract of the mutated catalog; (b) WAL replay reproduced the live
# graph byte-for-byte; (c) every gated scenario's apply_delta beat the
# full re-extract outright (a delta path that loses to a rebuild is a
# regression, not a feature).
python - <<'PY'
import json
with open("BENCH_delta.json") as fh:
    r = json.load(fh)
assert r["scenarios"], "no gated delta scenarios ran"
assert r["byte_identical"], "a delta scenario diverged from extract"
assert r["replay_byte_identical"], "WAL replay diverged from live graph"
assert r["replay_entries"] >= 1, "replay exercised an empty log"
losers = [
    s["name"] for s in r["scenarios"]
    if not s["delta_us"] < s["full_extract_us"]
]
assert not losers, f"delta apply lost to full re-extract in: {losers}"
print(
    "byte-identical over "
    f"{len(r['scenarios']) + len(r['informational'])} scenarios + replay "
    f"of {r['replay_entries']} entries; speedups: "
    + ", ".join(f"{s['name']}={s['speedup']:.2f}x" for s in r["scenarios"])
)
PY

echo "== serving tier (BENCH_serving.json) =="
# the smoke run above already ran the serving section and wrote the
# artifact; assert its claims here.  Gates: (a) continuous batching beats
# the synchronous flush baseline on p99 at equal offered QPS (the tier's
# reason to exist — a barrier-free scheduler that loses on tails is a
# regression); (b) batch occupancy stays above the committed floor (the
# bucket widths are not allowed to pad the win away); (c) the result
# cache actually hits on the repeated-query scenario; (d) LRU eviction
# under the byte budget is loss-free (byte-identical answers) and the
# budget genuinely forced evictions under a budget smaller than the
# packed sum; (e) no executable re-traced on reuse; (f) the absolute
# p99 stays under the committed ceiling in benchmarks/serving_baseline.json
# (full runs only — smoke sizes are not comparable to the baseline).
python - <<'PY'
import json
with open("BENCH_serving.json") as fh:
    r = json.load(fh)
with open("benchmarks/serving_baseline.json") as fh:
    base = json.load(fh)
cont, sync = r["continuous"], r["sync_flush"]
assert cont["p99_ms"] < sync["p99_ms"], (
    f"continuous p99 {cont['p99_ms']:.2f}ms lost to sync flush "
    f"{sync['p99_ms']:.2f}ms at {r['reference_qps']:.0f} qps"
)
assert cont["occupancy"] >= base["occupancy_min"], (
    f"occupancy {cont['occupancy']:.2f} below floor {base['occupancy_min']}"
)
rq = r["repeated_queries"]
assert rq["result_cache_hit_rate"] > base["result_cache_hit_rate_min"], (
    f"result cache never hit: {rq['result_cache_hit_rate']:.2f}"
)
mt = r["multi_tenant"]
assert mt["byte_identical"], "eviction/reload changed answer bytes"
assert mt["n_evictions"] > 0, "budget never forced an eviction"
assert mt["budget_bytes"] < mt["sum_packed_bytes"], "budget not binding"
assert r["bucket_churn"]["retraces"] == 0, "executable re-traced on reuse"
if not r["smoke"]:
    assert cont["p99_ms"] <= base["continuous_p99_ms_max"], (
        f"continuous p99 {cont['p99_ms']:.2f}ms over committed ceiling "
        f"{base['continuous_p99_ms_max']}ms"
    )
print(
    f"continuous p99 {cont['p99_ms']:.2f}ms < sync {sync['p99_ms']:.2f}ms "
    f"at {r['reference_qps']:.0f} qps; occupancy {cont['occupancy']:.2f}; "
    f"result-cache hit rate {rq['result_cache_hit_rate']:.2f}; "
    f"{mt['n_evictions']} evictions byte-identical under "
    f"{mt['budget_bytes']}B < {mt['sum_packed_bytes']}B"
)
PY

echo "== cost-based plans (BENCH_advisor.json) =="
# the smoke run above already ran the advisor section and wrote the
# artifact; assert its claims here.  Gates: (a) on every fixture the
# optimizer's chosen plan is never worse than the best hand-picked
# BENCH row config — wall time strictly (same-measurement equality
# when the chosen config IS a hand row) and peak residency under the
# tie-band semantics documented in benchmarks/bench_advisor.py; (b)
# the cost model's predicted peaks bound the measured peaks on every
# chosen plan (the soundness contract budget pruning relies on).
python - <<'PY2'
import json
with open("BENCH_advisor.json") as fh:
    r = json.load(fh)
assert r["fixtures"], "no advisor fixtures ran"
slow = [f["name"] for f in r["fixtures"] if not f["never_worse_time"]]
assert not slow, f"chosen plan lost on wall time in: {slow}"
fat = [f["name"] for f in r["fixtures"] if not f["never_worse_bytes"]]
assert not fat, f"chosen plan lost on peak residency in: {fat}"
assert r["all_never_worse"], "all_never_worse flag disagrees with rows"
unsound = [f["name"] for f in r["fixtures"] if not f["bound_ok"]]
assert not unsound, f"predicted peaks below measured peaks in: {unsound}"
assert r["all_bounds_ok"], "all_bounds_ok flag disagrees with rows"
print(
    "chosen plan never worse over "
    + ", ".join(
        f"{f['name']} ({f['chosen_is_hand_row'] or 'custom'} vs "
        f"{f['best_hand']})" for f in r["fixtures"]
    )
    + "; predicted bounds hold"
)
PY2

echo "== all gates passed =="
