#!/usr/bin/env bash
# One-command PR gate: tier-1 tests, tier-2 property tests, smoke benches.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 (unit + integration) =="
python -m pytest -x -q -m "not tier2"

echo "== tier-2 (property / statistical) =="
python -m pytest -q -m tier2

echo "== docs check (dead symbol references in README/DESIGN) =="
python scripts/check_docs.py

echo "== smoke benches (every section at toy sizes) =="
# the extraction section asserts sharded-extraction byte-identity and
# budget accounting (DESIGN.md §7) — an ExtractionBudget violation or a
# merge-step mismatch fails this step — and gates the out-of-core
# assembly path (DESIGN.md §8) via the extract_dblp_spill{2,7} rows:
# spilled peak resident assembly bytes must be strictly below the
# no-spill accumulation and the tree-reduce re-merge byte-identical
python -m benchmarks.run --smoke

echo "== kernels perf cells (BENCH_kernels.json) =="
# the full smoke run above already ran the kernels section and wrote the
# artifact; only assert its cells here (no duplicate interpret-mode sweep)
python - <<'PY'
import json
with open("BENCH_kernels.json") as fh:
    r = json.load(fh)
assert "fallback_rate" in r and "cells" in r and "pack" in r, r.keys()
assert r["fallback_rate"] == 0.0, f"kernel fell back to XLA: {r['cells']}"
print(
    f"fallback_rate={r['fallback_rate']} (old formula: "
    f"{r['fallback_rate_old_formula']}); pack speedup "
    f"{r['pack']['speedup']:.2f}x over {r['pack']['edges']} edges"
)
PY

echo "== all gates passed =="
