#!/usr/bin/env bash
# One-command PR gate: tier-1 tests, tier-2 property tests, smoke benches.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 (unit + integration) =="
python -m pytest -x -q -m "not tier2"

echo "== tier-2 (property / statistical) =="
python -m pytest -q -m tier2

echo "== smoke benches (every section at toy sizes) =="
python -m benchmarks.run --smoke

echo "== all gates passed =="
