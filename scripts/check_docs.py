#!/usr/bin/env python
"""Docs-rot gate: README.md / DESIGN.md must not reference dead symbols.

Every backticked token in the two top-level docs that looks like a code
identifier or a repo path is checked against the actual tree: paths must
exist, identifiers must occur somewhere in the code corpus (src/, tests/,
benchmarks/, examples/, scripts/).  A doc that names a function or file
deleted by a refactor fails scripts/check.sh here instead of rotting
silently — exactly the class of drift the PR-3/PR-4 refactors kept
producing.

On top of the token scan, REQUIRED_SECTIONS pins sections that later
code gates on: DESIGN.md §8 (spill + multi-host merge) and the README's
"Out-of-core assembly" subsection must exist — a doc reorganization that
drops one fails here, and because the sections exist their backticked
symbol references (``ShardSpillStore``, ``merge_spilled_graph``,
``MultihostSpillExtraction``, ...) go through the same dead-reference
scan as everything else.

Exit code 0 = clean; 1 = dead references / missing sections (stderr).
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ["README.md", "DESIGN.md"]
CODE_DIRS = ["src", "tests", "benchmarks", "examples", "scripts"]
CODE_EXT = {".py", ".sh", ".ini", ".json", ".md"}

# Sections the rest of the gate (tests, benches) references by name:
# each doc must contain every listed heading, verbatim prefix match.
REQUIRED_SECTIONS = {
    "DESIGN.md": [
        "## §6 ",
        "### Autotuned kernel sweep",
        "### Fused DEDUP-C epilogue",
        "### Measured-crossover dispatch",
        "## §7 ",
        "## §8 ",
        "## §9 ",
        "## §10 ",
        "## §11 ",
        "## §12 ",
    ],
    "README.md": [
        "## Algorithm library",
        "## Larger-than-memory extraction",
        "### Out-of-core assembly",
        "## Graphs that stay fresh",
        "## Serving many graphs",
        "## Planning an extraction",
    ],
}

# Tokens that are prose, math, or shell notation rather than symbol
# references; single letters and anything < 4 chars are skipped anyway.
ALLOW = {
    "pytest", "hypothesis", "numpy", "python", "jax", "pallas",
    "vmem", "smem", "hbm", "mosaic", "vllm", "csv", "jit",
}

_TOKEN = re.compile(r"`([^`\n]+)`")
_IDENT = re.compile(r"^[A-Za-z_][A-Za-z0-9_.]*$")
_PATHY = re.compile(r"^[A-Za-z0-9_.\-/]+$")


def _corpus() -> str:
    chunks = []
    for d in CODE_DIRS:
        for dirpath, _, files in os.walk(os.path.join(ROOT, d)):
            for f in files:
                if os.path.splitext(f)[1] in CODE_EXT:
                    chunks.append(f)  # filenames count as symbols too
                    path = os.path.join(dirpath, f)
                    try:
                        with open(path, encoding="utf-8") as fh:
                            chunks.append(fh.read())
                    except (OSError, UnicodeDecodeError):
                        pass
    chunks.extend(os.listdir(ROOT))
    return "\n".join(chunks)


def _path_exists(token: str) -> bool:
    token = token.rstrip("/")
    for base in ("", "src", os.path.join("src", "repro")):
        if os.path.exists(os.path.join(ROOT, base, token)):
            return True
    return False


def _check(token: str, corpus: str) -> bool:
    """True when the token resolves to something real."""
    token = token.strip().rstrip(")").removesuffix("(")
    if token.endswith("()"):
        token = token[:-2]
    if len(token) < 4 or token.lower() in ALLOW:
        return True
    if not any(c.isalpha() for c in token):
        return True
    if " " in token or "\t" in token:
        return True  # command lines / prose
    if token.startswith("--"):
        return token in corpus
    if "/" in token or token.endswith((".py", ".md", ".sh", ".json", ".ini")):
        if _path_exists(token) or _path_exists(token + ".py") or token in corpus:
            return True
        # module-path.attribute hybrid (`core/engine.propagate`): the
        # module file must exist and the attribute must occur in the tree
        if "." in token:
            mod, _, attr = token.partition(".")
            return _path_exists(mod + ".py") and attr in corpus
        return False
    if not (_IDENT.match(token) or _PATHY.match(token)):
        return True  # math / shell fragments like x[idx]=v
    if token in corpus:
        return True
    # dotted name: the module path or the final attribute must exist
    if "." in token:
        parts = token.split(".")
        as_path = os.path.join(*parts)
        if _path_exists(as_path + ".py") or _path_exists(as_path):
            return True
        return parts[-1] in corpus
    return False


def main() -> int:
    corpus = _corpus()
    dead = []
    missing_sections = []
    for doc in DOCS:
        with open(os.path.join(ROOT, doc), encoding="utf-8") as fh:
            text = fh.read()
        lines = text.splitlines()
        for heading in REQUIRED_SECTIONS.get(doc, []):
            if not any(l.startswith(heading) for l in lines):
                missing_sections.append((doc, heading))
        for lineno, line in enumerate(lines, 1):
            for token in _TOKEN.findall(line):
                if not _check(token, corpus):
                    dead.append((doc, lineno, token))
    if missing_sections:
        print("required doc sections missing:", file=sys.stderr)
        for doc, heading in missing_sections:
            print(f"  {doc}: `{heading}...`", file=sys.stderr)
    if dead:
        print("dead doc references (symbol/path not found in the tree):",
              file=sys.stderr)
        for doc, lineno, token in dead:
            print(f"  {doc}:{lineno}: `{token}`", file=sys.stderr)
    if dead or missing_sections:
        return 1
    n_tokens = sum(
        len(_TOKEN.findall(open(os.path.join(ROOT, d), encoding="utf-8").read()))
        for d in DOCS
    )
    n_sections = sum(len(v) for v in REQUIRED_SECTIONS.values())
    print(f"docs check: {n_tokens} backticked references in "
          f"{'/'.join(DOCS)} all resolve; {n_sections} required sections "
          "present")
    return 0


if __name__ == "__main__":
    sys.exit(main())
